//! Native model zoo: flat-parameter mini VGG / ResNet MLPs.
//!
//! Mirrors `python/compile/models.py` + `train_step.py`: the same family
//! structure (dense VGG stacks, pre-activation residual blocks with
//! zero-init second layers), the same masked cross-entropy contract, the
//! same optimizer update rules, and the `kernels/ref.py` gradient-moment
//! statistics. Parameter vectors use the JAX `ravel_pytree` layout (dict
//! keys sorted lexicographically, `b` before `w`, weights `[fan_in,
//! fan_out]` row-major) so snapshots interchange with the XLA backend.

use super::exec::{KernelTier, Pool};
use super::linalg::*;
use super::workspace::{PanelCache, Workspace};
use crate::runtime::backend::OptState;
use crate::util::rng::Rng;

pub const SGD_MOMENTUM: f32 = 0.9;
pub const ADAM_B1: f32 = 0.9;
pub const ADAM_B2: f32 = 0.999;
pub const ADAM_EPS: f32 = 1e-8;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    Vgg,
    Resnet,
}

/// One dense layer's location inside the flat parameter vector.
#[derive(Clone, Copy, Debug)]
pub struct DenseRef {
    /// Bias offset (length `n`).
    pub b: usize,
    /// Weight offset (`[k, n]` row-major).
    pub w: usize,
    pub k: usize,
    pub n: usize,
}

impl DenseRef {
    fn bias<'a>(&self, p: &'a [f32]) -> &'a [f32] {
        &p[self.b..self.b + self.n]
    }

    fn weight<'a>(&self, p: &'a [f32]) -> &'a [f32] {
        &p[self.w..self.w + self.k * self.n]
    }

    /// y = x @ w + b for a batch of `m` rows, into a reused buffer.
    fn forward_into(&self, pool: &Pool, p: &[f32], x: &[f32], m: usize, y: &mut Vec<f32>) {
        y.clear();
        y.resize(m * self.n, 0.0);
        matmul_acc(pool, x, self.weight(p), m, self.k, self.n, y);
        add_bias(pool, y, self.bias(p), m, self.n);
    }

    /// Input gradient only: dx = dy @ w^T, streamed through a
    /// generation-tagged packed panel of this layer's weights (`panels`,
    /// keyed by the weight offset, tagged with the step generation `gen`).
    #[allow(clippy::too_many_arguments)]
    fn backward_dx(
        &self,
        pool: &Pool,
        p: &[f32],
        dy: &[f32],
        m: usize,
        dx: &mut Vec<f32>,
        panels: &mut PanelCache,
        gen: u64,
    ) {
        dx.clear();
        dx.resize(m * self.k, 0.0);
        matmul_bt_ws(
            pool, panels, gen, self.w, dy, self.weight(p), m, self.k, self.n, dx,
        );
    }

    /// Accumulate weight/bias grads only (no input grad).
    ///
    /// PARITY: `col_sums`/`matmul_at` fold rows sequentially per output
    /// element INTO the existing values of `g` — the traveling-accumulator
    /// contract every bucket fold in the sharded ring relies on.
    fn backward_params(&self, pool: &Pool, x: &[f32], dy: &[f32], m: usize, g: &mut [f32]) {
        col_sums(pool, dy, m, self.n, &mut g[self.b..self.b + self.n]);
        matmul_at(pool, x, dy, m, self.k, self.n, &mut g[self.w..self.w + self.k * self.n]);
    }

    /// The contiguous gradient slice this dense owns: bias then weight
    /// (the ravel layout always places `w` right after the `n` bias lanes).
    fn grad_span(&self) -> GradStage {
        debug_assert_eq!(self.w, self.b + self.n, "bias/weight not contiguous");
        GradStage { offset: self.b, len: self.n + self.k * self.n }
    }
}

/// One backward stage's final slice of the flat gradient buffer, in
/// backward **completion order** (stage 0 finishes first). Boundaries are
/// static functions of the model layout — never of timing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GradStage {
    pub offset: usize,
    pub len: usize,
}

impl GradStage {
    pub fn end(&self) -> usize {
        self.offset + self.len
    }
}

/// One or more memory-adjacent completion stages flushed over the ring as
/// a unit: a contiguous `[offset, offset+len)` window of the flat gradient
/// plus the completion-order stage run that fills it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct GradBucket {
    pub offset: usize,
    pub len: usize,
    pub stages: std::ops::Range<usize>,
}

/// Static shape of one zoo model.
#[derive(Clone, Debug)]
pub struct ModelDef {
    pub name: &'static str,
    pub family: Family,
    /// VGG: hidden layers; ResNet: residual blocks.
    pub depth: usize,
    pub width: usize,
    pub feature_dim: usize,
    pub classes: usize,
}

/// Cached forward activations for the backward pass.
pub struct Acts {
    /// Post-ReLU activations: VGG — one per layer; ResNet — stem output
    /// followed by every block output (`depth + 1` entries).
    hs: Vec<Vec<f32>>,
    /// ResNet only: post-ReLU inner activations, one per block.
    us: Vec<Vec<f32>>,
    pub logits: Vec<f32>,
}

impl ModelDef {
    /// The zoo, mirroring `models.MODEL_ZOO` (mini depth ladder).
    pub fn zoo() -> Vec<ModelDef> {
        let m = |name, family, classes, depth| ModelDef {
            name,
            family,
            depth,
            width: 64,
            feature_dim: 128,
            classes,
        };
        vec![
            m("vgg11_mini", Family::Vgg, 10, 5),
            m("vgg16_mini", Family::Vgg, 10, 8),
            m("vgg19_mini", Family::Vgg, 10, 10),
            m("resnet34_mini", Family::Resnet, 100, 6),
            m("resnet50_mini", Family::Resnet, 100, 10),
        ]
    }

    pub fn dataset(&self) -> &'static str {
        if self.classes == 10 {
            "cifar10_syn"
        } else {
            "cifar100_syn"
        }
    }

    /// ravel_pytree layout for VGG: keys sort `head < layer0 < layer1 ...`,
    /// and `b < w` within each dense.
    fn vgg_refs(&self) -> (Vec<DenseRef>, DenseRef) {
        let (w, f, c) = (self.width, self.feature_dim, self.classes);
        let head = DenseRef { b: 0, w: c, k: w, n: c };
        let mut off = c + w * c;
        let mut layers = Vec::with_capacity(self.depth);
        for i in 0..self.depth {
            let k = if i == 0 { f } else { w };
            layers.push(DenseRef { b: off, w: off + w, k, n: w });
            off += w + k * w;
        }
        (layers, head)
    }

    /// ravel_pytree layout for ResNet: `block0 < ... < head < stem`,
    /// blocks `fc1 < fc2`, and `b < w` within each dense.
    fn resnet_refs(&self) -> (DenseRef, Vec<(DenseRef, DenseRef)>, DenseRef) {
        let (w, f, c) = (self.width, self.feature_dim, self.classes);
        let mut off = 0;
        let mut blocks = Vec::with_capacity(self.depth);
        for _ in 0..self.depth {
            let fc1 = DenseRef { b: off, w: off + w, k: w, n: w };
            off += w + w * w;
            let fc2 = DenseRef { b: off, w: off + w, k: w, n: w };
            off += w + w * w;
            blocks.push((fc1, fc2));
        }
        let head = DenseRef { b: off, w: off + c, k: w, n: c };
        off += c + w * c;
        let stem = DenseRef { b: off, w: off + w, k: f, n: w };
        (stem, blocks, head)
    }

    pub fn param_count(&self) -> usize {
        let (w, f, c) = (self.width, self.feature_dim, self.classes);
        match self.family {
            Family::Vgg => (c + w * c) + (w + f * w) + (self.depth - 1) * (w + w * w),
            Family::Resnet => self.depth * 2 * (w + w * w) + (c + w * c) + (w + f * w),
        }
    }

    /// Seeded He-init parameters (same distributions as `models.init_params`;
    /// not bit-identical to the JAX PRNG, by design — see DESIGN notes in
    /// the module docs). ResNet `fc2` weights start at zero so residual
    /// blocks are identity at init.
    pub fn init(&self, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed ^ fnv1a(self.name.as_bytes()));
        let mut p = vec![0.0f32; self.param_count()];
        let mut he = |p: &mut [f32], r: &DenseRef, zero: bool| {
            if zero {
                return; // biases are already zero; fc2 weights stay zero
            }
            let scale = (2.0 / r.k as f64).sqrt();
            for v in &mut p[r.w..r.w + r.k * r.n] {
                *v = (rng.normal() * scale) as f32;
            }
        };
        match self.family {
            Family::Vgg => {
                let (layers, head) = self.vgg_refs();
                for l in &layers {
                    he(&mut p, l, false);
                }
                he(&mut p, &head, false);
            }
            Family::Resnet => {
                let (stem, blocks, head) = self.resnet_refs();
                he(&mut p, &stem, false);
                for (fc1, fc2) in &blocks {
                    he(&mut p, fc1, false);
                    he(&mut p, fc2, true); // identity-start residual
                }
                he(&mut p, &head, false);
            }
        }
        p
    }

    /// Activation-slot counts in a workspace: (`hs` entries, `us` entries).
    fn act_slots(&self) -> (usize, usize) {
        match self.family {
            Family::Vgg => (self.depth, 0),
            Family::Resnet => (self.depth + 1, self.depth),
        }
    }

    /// Forward pass over `m` rows into workspace buffers (`ws.hs`, `ws.us`,
    /// `ws.logits`); allocation-free once the workspace is warm.
    pub fn forward_ws(&self, pool: &Pool, p: &[f32], x: &[f32], m: usize, ws: &mut Workspace) {
        let (n_hs, n_us) = self.act_slots();
        Workspace::ensure_slots(&mut ws.hs, n_hs);
        Workspace::ensure_slots(&mut ws.us, n_us);
        match self.family {
            Family::Vgg => {
                let (layers, head) = self.vgg_refs();
                layers[0].forward_into(pool, p, x, m, &mut ws.hs[0]);
                relu(pool, &mut ws.hs[0]);
                for li in 1..self.depth {
                    let (prev, rest) = ws.hs.split_at_mut(li);
                    layers[li].forward_into(pool, p, &prev[li - 1], m, &mut rest[0]);
                    relu(pool, &mut rest[0]);
                }
                head.forward_into(pool, p, &ws.hs[self.depth - 1], m, &mut ws.logits);
            }
            Family::Resnet => {
                let (stem, blocks, head) = self.resnet_refs();
                stem.forward_into(pool, p, x, m, &mut ws.hs[0]);
                relu(pool, &mut ws.hs[0]);
                for (i, (fc1, fc2)) in blocks.iter().enumerate() {
                    fc1.forward_into(pool, p, &ws.hs[i], m, &mut ws.us[i]);
                    relu(pool, &mut ws.us[i]);
                    let (prev, rest) = ws.hs.split_at_mut(i + 1);
                    let z = &mut rest[0];
                    fc2.forward_into(pool, p, &ws.us[i], m, z);
                    for (zi, hi) in z.iter_mut().zip(&prev[i]) {
                        *zi += *hi; // skip connection
                    }
                    relu(pool, z);
                }
                head.forward_into(pool, p, &ws.hs[self.depth], m, &mut ws.logits);
            }
        }
    }

    /// Backward pass from `ws.dlogits` through the activations cached by
    /// [`Self::forward_ws`], accumulating the flat parameter gradient into
    /// `ws.grad` (zeroed first). Clobbers `ws.dh`/`ws.du`/`ws.dtmp`.
    pub fn backward_ws(&self, pool: &Pool, p: &[f32], x: &[f32], m: usize, ws: &mut Workspace) {
        ws.grad.clear();
        ws.grad.resize(self.param_count(), 0.0);
        self.backward_acc_ws(pool, p, x, m, ws);
    }

    /// [`Self::backward_ws`] without the gradient zeroing: folds this
    /// batch's per-sample contributions INTO the existing `ws.grad`
    /// accumulator. The batch-dim reductions (`matmul_at`, `col_sums`) are
    /// strictly sequential over rows per output element, so chaining
    /// contiguous row slices through this entry point in row order
    /// reproduces the fused backward **bit for bit** — the sharded data
    /// plane's correctness oracle hinges on exactly this property.
    pub fn backward_acc_ws(&self, pool: &Pool, p: &[f32], x: &[f32], m: usize, ws: &mut Workspace) {
        debug_assert_eq!(ws.grad.len(), self.param_count());
        // PARITY: stages run strictly in completion order; the fused
        // backward IS the staged backward with zero wire latency between
        // stages, so overlapped ≡ bulk ≡ fused holds by construction.
        for k in 0..self.n_stages() {
            self.backward_stage_prep(pool, p, m, ws, k);
            self.backward_stage_fold(pool, p, x, m, ws, k);
        }
    }

    /// Number of backward completion stages (the bucket-able units): VGG
    /// folds the head then each hidden layer; ResNet folds the head, each
    /// residual block (fc1+fc2 as one unit), then the stem.
    pub fn n_stages(&self) -> usize {
        match self.family {
            Family::Vgg => self.depth + 1,
            Family::Resnet => self.depth + 2,
        }
    }

    /// Gradient slices in backward completion order. Slices are disjoint
    /// and tile `[0, param_count)`, but completion order is NOT memory
    /// order (the head lives at the bottom of the VGG ravel yet finishes
    /// first), which is why bucket coalescing checks memory adjacency.
    pub fn grad_stages(&self) -> Vec<GradStage> {
        let mut out = Vec::with_capacity(self.n_stages());
        match self.family {
            Family::Vgg => {
                let (layers, head) = self.vgg_refs();
                out.push(head.grad_span());
                for i in (0..self.depth).rev() {
                    out.push(layers[i].grad_span());
                }
            }
            Family::Resnet => {
                let (stem, blocks, head) = self.resnet_refs();
                out.push(head.grad_span());
                for i in (0..self.depth).rev() {
                    let (fc1, fc2) = &blocks[i];
                    out.push(GradStage {
                        offset: fc1.b,
                        len: fc2.grad_span().end() - fc1.b,
                    });
                }
                out.push(stem.grad_span());
            }
        }
        out
    }

    /// Deterministic bucket plan: walk stages in completion order, merging
    /// a stage into the open bucket while the bucket is under
    /// `target_bytes` AND the stage is memory-adjacent to it (so every
    /// bucket stays one contiguous `[offset, len)` window). `0` yields one
    /// bucket per stage; anything >= the model's byte size yields a single
    /// whole-model bucket. Pure layout function — identical on every rank.
    pub fn bucket_plan(&self, target_bytes: usize) -> Vec<GradBucket> {
        let stages = self.grad_stages();
        if target_bytes >= self.param_count() * 4 {
            return vec![GradBucket { offset: 0, len: self.param_count(), stages: 0..stages.len() }];
        }
        let target = target_bytes.max(1);
        let mut plan: Vec<GradBucket> = Vec::new();
        for (k, s) in stages.iter().enumerate() {
            if let Some(b) = plan.last_mut() {
                let adjacent = s.end() == b.offset || s.offset == b.offset + b.len;
                if adjacent && b.len * 4 < target {
                    b.offset = b.offset.min(s.offset);
                    b.len += s.len;
                    b.stages.end = k + 1;
                    continue;
                }
            }
            plan.push(GradBucket { offset: s.offset, len: s.len, stages: k..k + 1 });
        }
        plan
    }

    /// Recover the completion-order stage run backing a received bucket
    /// window, starting from the shard's stage cursor. Returns `None` when
    /// `[offset, offset+len)` is not exactly the union of a stage run
    /// beginning at `from_stage` — the shard-side guard that a leader and
    /// worker disagreeing on the bucket plan fails loudly, not silently.
    pub fn stages_for_range(
        &self,
        from_stage: usize,
        offset: usize,
        len: usize,
    ) -> Option<std::ops::Range<usize>> {
        let stages = self.grad_stages();
        let (mut lo, mut hi, mut total) = (usize::MAX, 0usize, 0usize);
        let mut k = from_stage;
        while k < stages.len() && total < len {
            let s = stages[k];
            lo = lo.min(s.offset);
            hi = hi.max(s.end());
            total += s.len;
            k += 1;
        }
        (total == len && lo == offset && hi == offset + len).then_some(from_stage..k)
    }

    /// ZeRO-style owner partition of the flat ravel_pytree parameter
    /// buffer: `plan_rows` extended from batch rows to parameters. Cuts
    /// the buffer into one contiguous `[start, end)` float range per
    /// shard (shard order; inactive shards get empty ranges), with every
    /// cut on a `bucket_plan(target_bytes)` bucket boundary so the PR 7
    /// overlap machinery (bucket hops, stage cursors) composes with
    /// ownership unchanged. Quotas are balanced, and an inactive shard's
    /// quota folds onto survivors through the same `sim::elastic`
    /// redistribution batch quotas use — ownership under churn follows
    /// the exact policy the row plan already follows.
    ///
    /// Ownership decides who applies which optimizer slice and the
    /// wire/memory accounting; it never changes how gradients fold, so
    /// it is parity-neutral by construction.
    pub fn param_partition(
        &self,
        active: &[bool],
        target_bytes: usize,
    ) -> Vec<std::ops::Range<usize>> {
        let pc = self.param_count();
        let n = active.len();
        let mut counts: Vec<usize> =
            (0..n).map(|s| pc / n + usize::from(s < pc % n)).collect();
        let caps = vec![pc; n];
        for s in 0..n {
            if !active[s] && counts[s] > 0 {
                crate::sim::elastic::redistribute_freed(
                    counts[s],
                    &mut counts,
                    active,
                    &caps,
                    pc,
                );
                counts[s] = 0;
            }
        }
        // Legal cut points: bucket END boundaries in memory order.
        let mut ends: Vec<usize> = self
            .bucket_plan(target_bytes)
            .iter()
            .map(|b| b.offset + b.len)
            .collect();
        ends.sort_unstable();
        // Snap each cumulative quota to the nearest boundary (ties take
        // the lower one), never moving backwards; the last active shard
        // always closes at `pc` so the ranges tile the buffer exactly.
        let last_active = active.iter().rposition(|&a| a);
        let mut out = Vec::with_capacity(n);
        let (mut cum, mut at) = (0usize, 0usize);
        for s in 0..n {
            if !active[s] {
                out.push(at..at);
                continue;
            }
            cum += counts[s];
            let end = if Some(s) == last_active {
                pc
            } else {
                let mut best = at;
                for &e in &ends {
                    if (e as i64 - cum as i64).abs() < (best as i64 - cum as i64).abs() {
                        best = e;
                    }
                }
                best.max(at)
            };
            out.push(at..end);
            at = end;
        }
        out
    }

    /// Stage `k`'s dx-propagation: every op needed before the stage's fold
    /// that does NOT read or write `ws.grad`. On a shard this runs as soon
    /// as stage `k-1`'s fold is done — overlapping the previous bucket's
    /// wire hop — because it never touches the traveling accumulator.
    pub fn backward_stage_prep(&self, pool: &Pool, p: &[f32], m: usize, ws: &mut Workspace, k: usize) {
        let gen = ws.gen;
        match self.family {
            Family::Vgg => {
                let (layers, head) = self.vgg_refs();
                match k {
                    0 => {}
                    1 => {
                        head.backward_dx(pool, p, &ws.dlogits, m, &mut ws.dh, &mut ws.panels, gen);
                        relu_backward(pool, &mut ws.dh, &ws.hs[self.depth - 1]);
                    }
                    _ => {
                        let i = self.depth - k; // layer this stage folds
                        layers[i + 1].backward_dx(
                            pool, p, &ws.dh, m, &mut ws.dtmp, &mut ws.panels, gen,
                        );
                        std::mem::swap(&mut ws.dh, &mut ws.dtmp);
                        relu_backward(pool, &mut ws.dh, &ws.hs[i]);
                    }
                }
            }
            Family::Resnet => {
                let (_, blocks, head) = self.resnet_refs();
                match k {
                    0 => {}
                    1 => {
                        head.backward_dx(pool, p, &ws.dlogits, m, &mut ws.dh, &mut ws.panels, gen);
                        relu_backward(pool, &mut ws.dh, &ws.hs[self.depth]);
                    }
                    _ => {
                        // Descend one activation level: the previous
                        // stage's block (index j) routes its fc1 input
                        // gradient down, joins the residual skip, and
                        // gates through hs[j]'s ReLU.
                        let j = self.depth + 1 - k;
                        blocks[j].0.backward_dx(
                            pool, p, &ws.du, m, &mut ws.dtmp, &mut ws.panels, gen,
                        );
                        for (a, b) in ws.dh.iter_mut().zip(&ws.dtmp) {
                            *a += *b; // residual: dz flows to h_in directly too
                        }
                        relu_backward(pool, &mut ws.dh, &ws.hs[j]);
                    }
                }
            }
        }
    }

    /// Stage `k`'s parameter-gradient fold: accumulates INTO `ws.grad`
    /// exactly within `grad_stages()[k]`'s slice.
    ///
    /// PARITY: fold `k` must see the upstream shard's accumulator already
    /// seeded in its slice before running — the sequential per-element row
    /// fold continues from whatever is in the buffer, which is the whole
    /// bitwise-parity mechanism of the bucketed ring.
    pub fn backward_stage_fold(
        &self,
        pool: &Pool,
        p: &[f32],
        x: &[f32],
        m: usize,
        ws: &mut Workspace,
        k: usize,
    ) {
        let gen = ws.gen;
        match self.family {
            Family::Vgg => {
                let (layers, head) = self.vgg_refs();
                if k == 0 {
                    head.backward_params(pool, &ws.hs[self.depth - 1], &ws.dlogits, m, &mut ws.grad);
                } else {
                    let i = self.depth - k;
                    if i == 0 {
                        layers[0].backward_params(pool, x, &ws.dh, m, &mut ws.grad);
                    } else {
                        layers[i].backward_params(pool, &ws.hs[i - 1], &ws.dh, m, &mut ws.grad);
                    }
                }
            }
            Family::Resnet => {
                let (stem, blocks, head) = self.resnet_refs();
                if k == 0 {
                    head.backward_params(pool, &ws.hs[self.depth], &ws.dlogits, m, &mut ws.grad);
                } else if k == self.depth + 1 {
                    stem.backward_params(pool, x, &ws.dh, m, &mut ws.grad);
                } else {
                    let i = self.depth - k;
                    let (fc1, fc2) = &blocks[i];
                    // dh is dz = d(loss)/d(h_in + fc2(u)) after prep's ReLU.
                    fc2.backward_params(pool, &ws.us[i], &ws.dh, m, &mut ws.grad);
                    fc2.backward_dx(pool, p, &ws.dh, m, &mut ws.du, &mut ws.panels, gen);
                    relu_backward(pool, &mut ws.du, &ws.us[i]);
                    fc1.backward_params(pool, &ws.hs[i], &ws.du, m, &mut ws.grad);
                }
            }
        }
    }

    /// Forward pass over `m` rows, caching activations for backward.
    /// Compatibility wrapper over [`Self::forward_ws`] (sequential, owns
    /// its buffers) — tests and one-off callers; hot paths go through the
    /// workspace API.
    pub fn forward(&self, p: &[f32], x: &[f32], m: usize) -> Acts {
        let mut ws = Workspace::default();
        self.forward_ws(&Pool::sequential(), p, x, m, &mut ws);
        let (n_hs, n_us) = self.act_slots();
        Acts {
            hs: ws.hs.drain(..n_hs).collect(),
            us: ws.us.drain(..n_us).collect(),
            logits: std::mem::take(&mut ws.logits),
        }
    }

    /// Backward pass: gradient of the scalar loss w.r.t. the flat params,
    /// given `dlogits` (loss gradient at the logits). Compatibility wrapper
    /// over [`Self::backward_ws`].
    pub fn backward(&self, p: &[f32], acts: &Acts, x: &[f32], dlogits: &[f32], m: usize) -> Vec<f32> {
        let mut ws = Workspace {
            hs: acts.hs.clone(),
            us: acts.us.clone(),
            dlogits: dlogits.to_vec(),
            ..Default::default()
        };
        ws.begin_step();
        self.backward_ws(&Pool::sequential(), p, x, m, &mut ws);
        std::mem::take(&mut ws.grad)
    }
}

/// Masked cross-entropy + metrics + logits gradient, mirroring
/// `models.masked_loss_and_metrics`: padded rows (mask 0) contribute exactly
/// zero to loss, gradient and the `correct` vector.
pub struct LossOut {
    pub loss: f32,
    pub acc: f32,
    pub correct: Vec<f32>,
    pub dlogits: Vec<f32>,
}

pub fn masked_ce_loss(logits: &[f32], y: &[i32], mask: &[f32], m: usize, n: usize) -> LossOut {
    let (mut logp, mut loss_terms, mut correct, mut dlogits) =
        (Vec::new(), Vec::new(), Vec::new(), Vec::new());
    let (loss, acc) = masked_ce_loss_ws(
        &Pool::sequential(),
        logits, y, mask, m, n, &mut logp, &mut loss_terms, &mut correct, &mut dlogits,
    );
    LossOut { loss, acc, correct, dlogits }
}

/// [`masked_ce_loss`] into reused workspace buffers; returns (loss, acc).
///
/// Implemented as the per-row kernel ([`masked_ce_rows`]) followed by the
/// row-order fold ([`fold_masked_ce`]) — exactly the decomposition the
/// sharded data plane replays across workers, so fused and sharded
/// execution share one source of truth (and stay bit-identical).
#[allow(clippy::too_many_arguments)]
pub fn masked_ce_loss_ws(
    pool: &Pool,
    logits: &[f32],
    y: &[i32],
    mask: &[f32],
    m: usize,
    n: usize,
    logp: &mut Vec<f32>,
    loss_terms: &mut Vec<f32>,
    correct: &mut Vec<f32>,
    dlogits: &mut Vec<f32>,
) -> (f32, f32) {
    // PARITY: sequential left-to-right mask fold — the sharded backend
    // computes the same denominator over the full mask before splitting
    // rows, so this association must never change (bit-identical losses).
    let denom: f32 = mask.iter().sum::<f32>().max(1.0);
    masked_ce_rows(pool, logits, y, mask, m, n, denom, logp, loss_terms, correct, dlogits);
    fold_masked_ce(loss_terms, correct, denom)
}

/// Per-row masked-CE pieces for `m` rows that may be a contiguous slice of
/// a larger fused batch: row-wise log-softmax, per-row loss terms
/// (`-logp[y_i] * mask_i`), per-row masked correctness, and `dlogits`
/// scaled by the **global** `denom` (the fused batch's mask sum, not this
/// slice's). Every output is a pure function of its own row, so a shard
/// computing its rows in isolation produces bit-identical values to the
/// fused computation over the whole batch.
#[allow(clippy::too_many_arguments)]
pub fn masked_ce_rows(
    pool: &Pool,
    logits: &[f32],
    y: &[i32],
    mask: &[f32],
    m: usize,
    n: usize,
    denom: f32,
    logp: &mut Vec<f32>,
    loss_terms: &mut Vec<f32>,
    correct: &mut Vec<f32>,
    dlogits: &mut Vec<f32>,
) {
    logp.clear();
    logp.resize(m * n, 0.0);
    log_softmax(pool, logits, m, n, logp);
    loss_terms.clear();
    loss_terms.resize(m, 0.0);
    correct.clear();
    correct.resize(m, 0.0);
    dlogits.clear();
    dlogits.resize(m * n, 0.0);
    if m == 0 || n == 0 {
        return;
    }
    // Rows are independent (see the doc above), so the per-row pieces are
    // row-partitioned across the pool — every chunk plan is BITWISE
    // identical to the sequential loop.
    let per = if pool.tier() == KernelTier::Scalar {
        m
    } else {
        pool.rows_per_chunk(m, 8 * n)
    };
    if per >= m {
        ce_rows_chunk(logits, y, mask, logp, n, denom, loss_terms, correct, dlogits);
        return;
    }
    let logp: &[f32] = logp;
    pool.run(
        logits
            .chunks(per * n)
            .zip(logp.chunks(per * n))
            .zip(y.chunks(per))
            .zip(mask.chunks(per))
            .zip(loss_terms.chunks_mut(per))
            .zip(correct.chunks_mut(per))
            .zip(dlogits.chunks_mut(per * n))
            .map(|((((((lc, lpc), yc), mc), ltc), cc), dc)| {
                move || ce_rows_chunk(lc, yc, mc, lpc, n, denom, ltc, cc, dc)
            })
            .collect(),
    );
}

/// The per-row CE body over one contiguous row chunk (`y.len()` rows):
/// loss term, first-max-wins argmax correctness, and the `dlogits` row
/// scaled by the global `denom`. Pure per-row outputs — chunking is
/// invisible to the results.
#[allow(clippy::too_many_arguments)]
fn ce_rows_chunk(
    logits: &[f32],
    y: &[i32],
    mask: &[f32],
    logp: &[f32],
    n: usize,
    denom: f32,
    loss_terms: &mut [f32],
    correct: &mut [f32],
    dlogits: &mut [f32],
) {
    let m = y.len();
    for i in 0..m {
        let yi = y[i] as usize;
        debug_assert!(yi < n, "label {yi} out of range {n}");
        let lrow = &logp[i * n..(i + 1) * n];
        loss_terms[i] = -lrow[yi] * mask[i];
        // argmax (first max wins, matching jnp.argmax).
        let mut best = 0;
        for j in 1..n {
            if logits[i * n + j] > logits[i * n + best] {
                best = j;
            }
        }
        if best == yi {
            correct[i] = mask[i];
        }
        let scale = mask[i] / denom;
        if scale != 0.0 {
            let drow = &mut dlogits[i * n..(i + 1) * n];
            for j in 0..n {
                drow[j] = lrow[j].exp() * scale;
            }
            drow[yi] -= scale;
        }
    }
}

/// Fold per-row loss terms and correctness into `(loss, acc)`: sequential
/// f64 sums in row order, divided by `denom`. Chaining
/// [`fold_masked_ce_partial`] over contiguous row slices in order yields
/// the identical accumulator sequence, which is how the sharded leader
/// reconstructs the fused loss bit for bit.
pub fn fold_masked_ce(loss_terms: &[f32], correct: &[f32], denom: f32) -> (f32, f32) {
    let (mut loss, mut acc) = (0.0f64, 0.0f64);
    fold_masked_ce_partial(loss_terms, correct, &mut loss, &mut acc);
    (
        (loss / denom as f64) as f32,
        (acc / denom as f64) as f32,
    )
}

/// Accumulate one row slice's loss terms / correctness into the running
/// f64 sums (strictly in row order).
pub fn fold_masked_ce_partial(
    loss_terms: &[f32],
    correct: &[f32],
    loss_sum: &mut f64,
    acc_sum: &mut f64,
) {
    for &t in loss_terms {
        *loss_sum += t as f64;
    }
    for &c in correct {
        *acc_sum += c as f64;
    }
}

/// The paper's §IV-B gradient-normalization statistics, exactly as
/// `kernels/ref.py::normalized_grad_stats_ref` with `n = len(g)`:
/// `sigma_norm = std(g) / (rms(g) + 1e-8)`. Returns
/// `(sigma_norm, sigma_norm^2, grad_l2)`.
pub fn normalized_grad_stats(g: &[f32]) -> (f32, f32, f32) {
    let n = g.len() as f64;
    let mut s = 0.0f64;
    let mut ss = 0.0f64;
    for &v in g {
        let v = v as f64;
        s += v;
        ss += v * v;
    }
    let mean = s / n;
    let var = (ss / n - mean * mean).max(0.0);
    let rms = (ss / n).sqrt();
    let sigma = var.sqrt() / (rms + 1e-8);
    (sigma as f32, (sigma * sigma) as f32, ss.sqrt() as f32)
}

/// SGD with momentum (`train_step.py` `optimizer == "sgd"`).
pub fn apply_sgd(pool: &Pool, state: &mut OptState, g: &[f32], lr: f32) {
    debug_assert_eq!(state.params.len(), g.len());
    debug_assert_eq!(state.m.len(), g.len());
    state.step += 1.0;
    apply_sgd_slice(pool, &mut state.params, &mut state.m, g, lr);
}

/// One contiguous slice of the SGD-with-momentum update — the ZeRO
/// owner's unit of optimizer work. `params`/`m`/`g` are the pre-sliced
/// windows of one parameter range.
///
/// PARITY: the update is elementwise (no cross-index reduction), so
/// applying the full vector as any tiling of disjoint slices, in any
/// order — including the pool's chunk partition inside
/// `linalg::sgd_apply` — produces params/momentum bit-identical to the
/// fused `apply_sgd` loop. The step counter advances once per *step*,
/// not per slice — callers bump `OptState::step` before slicing.
pub fn apply_sgd_slice(pool: &Pool, params: &mut [f32], m: &mut [f32], g: &[f32], lr: f32) {
    debug_assert_eq!(params.len(), g.len());
    debug_assert_eq!(m.len(), g.len());
    sgd_apply(pool, params, m, g, lr, SGD_MOMENTUM);
}

/// Adam with bias correction (`train_step.py` / `policy.py::_adam`).
pub fn apply_adam(pool: &Pool, state: &mut OptState, g: &[f32], lr: f32) {
    debug_assert_eq!(state.params.len(), g.len());
    debug_assert_eq!(state.m.len(), g.len());
    debug_assert_eq!(state.v.len(), g.len());
    state.step += 1.0;
    let t = state.step as f64;
    apply_adam_slice(pool, &mut state.params, &mut state.m, &mut state.v, g, lr, t);
}

/// One contiguous slice of the Adam update at an explicit step count
/// `t` (the bias-correction exponent). `params`/`m`/`v`/`g` are the
/// pre-sliced windows of one parameter range.
///
/// PARITY: elementwise like `apply_sgd_slice` — slice tiling and
/// application order (pool chunks included) never change a bit; `t` is
/// passed in so every slice of one step sees the identical bias
/// correction, computed once here rather than per chunk.
pub fn apply_adam_slice(
    pool: &Pool,
    params: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    g: &[f32],
    lr: f32,
    t: f64,
) {
    debug_assert_eq!(params.len(), g.len());
    debug_assert_eq!(m.len(), g.len());
    debug_assert_eq!(v.len(), g.len());
    let c1 = (1.0 - (ADAM_B1 as f64).powf(t)) as f32;
    let c2 = (1.0 - (ADAM_B2 as f64).powf(t)) as f32;
    adam_apply(pool, params, m, v, g, lr, ADAM_B1, ADAM_B2, ADAM_EPS, c1, c2);
}

/// FNV-1a over bytes — stable model-name → seed-stream tag.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn def(name: &str) -> ModelDef {
        ModelDef::zoo().into_iter().find(|m| m.name == name).unwrap()
    }

    #[test]
    fn param_counts_match_ravel_pytree_layout() {
        // Hand-computed from the python layer shapes (models.py).
        assert_eq!(def("vgg11_mini").param_count(), 25_546);
        assert_eq!(def("vgg16_mini").param_count(), 38_026);
        assert_eq!(def("vgg19_mini").param_count(), 46_346);
        assert_eq!(def("resnet34_mini").param_count(), 64_676);
        assert_eq!(def("resnet50_mini").param_count(), 97_956);
    }

    #[test]
    fn layout_refs_tile_the_vector_exactly() {
        for m in ModelDef::zoo() {
            let pc = m.param_count();
            let mut covered = vec![false; pc];
            let mut mark = |r: &DenseRef| {
                for i in r.b..r.b + r.n {
                    assert!(!covered[i], "{}: bias overlap at {i}", m.name);
                    covered[i] = true;
                }
                for i in r.w..r.w + r.k * r.n {
                    assert!(!covered[i], "{}: weight overlap at {i}", m.name);
                    covered[i] = true;
                }
            };
            match m.family {
                Family::Vgg => {
                    let (layers, head) = m.vgg_refs();
                    layers.iter().for_each(&mut mark);
                    mark(&head);
                }
                Family::Resnet => {
                    let (stem, blocks, head) = m.resnet_refs();
                    mark(&stem);
                    for (a, b) in &blocks {
                        mark(a);
                        mark(b);
                    }
                    mark(&head);
                }
            }
            assert!(covered.iter().all(|&c| c), "{}: layout has holes", m.name);
        }
    }

    #[test]
    fn grad_stages_tile_the_vector_in_completion_order() {
        for m in ModelDef::zoo() {
            let stages = m.grad_stages();
            assert_eq!(stages.len(), m.n_stages(), "{}", m.name);
            let mut covered = vec![false; m.param_count()];
            for s in &stages {
                assert!(s.len > 0, "{}: empty stage", m.name);
                for i in s.offset..s.end() {
                    assert!(!covered[i], "{}: stage overlap at {i}", m.name);
                    covered[i] = true;
                }
            }
            assert!(covered.iter().all(|&c| c), "{}: stages have holes", m.name);
            // Stage 0 is the head (the first slice backward finalizes).
            let c = m.classes;
            let w = m.width;
            match m.family {
                Family::Vgg => assert_eq!(stages[0], GradStage { offset: 0, len: c + w * c }),
                Family::Resnet => assert_eq!(stages[0].len, c + w * c),
            }
        }
    }

    #[test]
    fn bucket_plans_are_contiguous_and_recoverable() {
        for m in ModelDef::zoo() {
            let stages = m.grad_stages();
            let pc = m.param_count();
            for target_bytes in [0usize, 1, 8 << 10, 32 << 10, 4 * pc, usize::MAX / 8] {
                let plan = m.bucket_plan(target_bytes);
                // Stage runs concatenate to exactly 0..n_stages.
                let mut next = 0usize;
                let mut total = 0usize;
                for b in &plan {
                    assert_eq!(b.stages.start, next, "{}: gap in stage runs", m.name);
                    next = b.stages.end;
                    total += b.len;
                    // Bucket window is exactly the union of its stages.
                    let lo = b.stages.clone().map(|k| stages[k].offset).min().unwrap();
                    let hi = b.stages.clone().map(|k| stages[k].end()).max().unwrap();
                    let sum: usize = b.stages.clone().map(|k| stages[k].len).sum();
                    assert_eq!((b.offset, b.len), (lo, hi - lo), "{}", m.name);
                    assert_eq!(sum, b.len, "{}: bucket window has holes", m.name);
                    // The shard can recover the run from the wire fields.
                    assert_eq!(
                        m.stages_for_range(b.stages.start, b.offset, b.len),
                        Some(b.stages.clone()),
                        "{}: stages_for_range disagrees",
                        m.name
                    );
                }
                assert_eq!(next, m.n_stages(), "{}", m.name);
                assert_eq!(total, pc, "{}: plan does not tile the gradient", m.name);
            }
            assert_eq!(m.bucket_plan(0).len(), m.n_stages(), "{}", m.name);
            assert_eq!(m.bucket_plan(4 * pc).len(), 1, "{}", m.name);
            // A mid-run or misaligned window must not resolve.
            assert_eq!(m.stages_for_range(1, 0, pc), None);
            assert_eq!(m.stages_for_range(0, 0, pc - 1), None);
            assert_eq!(m.stages_for_range(0, 1, stages[0].len), None);
        }
    }

    #[test]
    fn param_partition_tiles_on_bucket_boundaries() {
        for m in ModelDef::zoo() {
            let pc = m.param_count();
            for target_bytes in [0usize, 32 << 10, 4 * pc] {
                let mut ends: Vec<usize> = m
                    .bucket_plan(target_bytes)
                    .iter()
                    .map(|b| b.offset + b.len)
                    .collect();
                ends.sort_unstable();
                for n in [1usize, 2, 4, 7, 16] {
                    let part = m.param_partition(&vec![true; n], target_bytes);
                    assert_eq!(part.len(), n, "{}", m.name);
                    let mut at = 0usize;
                    for r in &part {
                        assert_eq!(r.start, at, "{}: ranges must be contiguous", m.name);
                        at = r.end;
                        // Every cut sits on a bucket boundary (or 0/pc).
                        assert!(
                            r.end == 0 || ends.contains(&r.end),
                            "{}: cut {} off bucket boundaries (n={n})",
                            m.name,
                            r.end
                        );
                    }
                    assert_eq!(at, pc, "{}: partition does not tile the buffer", m.name);
                }
                // n = 1 owns everything.
                assert_eq!(m.param_partition(&[true], target_bytes), vec![0..pc]);
            }
            // Inactive shards own nothing; survivors absorb their quota.
            let part = m.param_partition(&[true, false, true, true], 0);
            assert!(part[1].is_empty(), "{}", m.name);
            assert_eq!(
                part.iter().map(|r| r.len()).sum::<usize>(),
                m.param_count(),
                "{}",
                m.name
            );
        }
    }

    #[test]
    fn slice_optimizer_application_matches_fused_bitwise() {
        // PARITY oracle for the ZeRO owner update: the full vector applied
        // as partition slices (any legal partition) is bit-identical to the
        // fused apply_sgd / apply_adam — the property that lets each shard
        // own only its optimizer slice.
        let m = def("vgg11_mini");
        let pc = m.param_count();
        let mut rng = crate::util::rng::Rng::new(77);
        let g: Vec<f32> = (0..pc).map(|_| rng.normal() as f32).collect();
        let params = m.init(3);
        for opt in ["sgd", "adam"] {
            let mut fused = OptState {
                params: params.clone(),
                m: vec![0.0; pc],
                v: vec![0.0; if opt == "adam" { pc } else { 1 }],
                step: 0.0,
            };
            let mut sliced = fused.clone();
            for step in 0..3 {
                let seq = Pool::sequential();
                if opt == "sgd" {
                    apply_sgd(&seq, &mut fused, &g, 0.05);
                    sliced.step += 1.0;
                    for r in m.param_partition(&vec![true; 4], 0) {
                        apply_sgd_slice(
                            &seq,
                            &mut sliced.params[r.clone()],
                            &mut sliced.m[r.clone()],
                            &g[r],
                            0.05,
                        );
                    }
                } else {
                    apply_adam(&seq, &mut fused, &g, 0.002);
                    sliced.step += 1.0;
                    let t = sliced.step as f64;
                    for r in m.param_partition(&vec![true; 4], 0) {
                        apply_adam_slice(
                            &seq,
                            &mut sliced.params[r.clone()],
                            &mut sliced.m[r.clone()],
                            &mut sliced.v[r.clone()],
                            &g[r],
                            0.002,
                            t,
                        );
                    }
                }
                let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&fused.params), bits(&sliced.params), "{opt} step {step}");
                assert_eq!(bits(&fused.m), bits(&sliced.m), "{opt} step {step}");
                if opt == "adam" {
                    assert_eq!(bits(&fused.v), bits(&sliced.v), "{opt} step {step}");
                }
            }
        }
    }

    #[test]
    fn stage_folds_write_only_their_declared_slice() {
        // Run the staged backward one stage at a time against a sentinel
        // gradient buffer: prep never touches grad, and fold k writes only
        // inside grad_stages()[k] — the property that makes shipping bucket
        // k over the wire while stage k+1 computes safe.
        use super::super::exec::Pool;
        use super::super::workspace::Workspace;
        for name in ["vgg11_mini", "resnet34_mini"] {
            let m = def(name);
            let p = m.init(6);
            let mut rng = crate::util::rng::Rng::new(23);
            let rows = 5usize;
            let x: Vec<f32> = (0..rows * m.feature_dim).map(|_| rng.normal() as f32).collect();
            let y: Vec<i32> = (0..rows).map(|_| rng.below(m.classes) as i32).collect();
            let mask = vec![1.0f32; rows];
            let pool = Pool::sequential();

            let fused = {
                let acts = m.forward(&p, &x, rows);
                let lo = masked_ce_loss(&acts.logits, &y, &mask, rows, m.classes);
                m.backward(&p, &acts, &x, &lo.dlogits, rows)
            };

            let mut ws = Workspace::default();
            ws.begin_step();
            m.forward_ws(&pool, &p, &x, rows, &mut ws);
            let logits = std::mem::take(&mut ws.logits);
            let (mut lp, mut lt, mut cor, mut dl) = (Vec::new(), Vec::new(), Vec::new(), Vec::new());
            masked_ce_rows(&pool, &logits, &y, &mask, rows, m.classes, rows as f32, &mut lp, &mut lt, &mut cor, &mut dl);
            ws.logits = logits;
            ws.dlogits = dl;
            ws.grad.clear();
            ws.grad.resize(m.param_count(), 0.0);

            let stages = m.grad_stages();
            for k in 0..m.n_stages() {
                let before = ws.grad.clone();
                m.backward_stage_prep(&pool, &p, rows, &mut ws, k);
                assert_eq!(ws.grad, before, "{name}: prep {k} touched grad");
                m.backward_stage_fold(&pool, &p, &x, rows, &mut ws, k);
                let s = stages[k];
                for (i, (a, b)) in ws.grad.iter().zip(&before).enumerate() {
                    if i < s.offset || i >= s.end() {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "{name}: fold {k} wrote outside its slice at {i}"
                        );
                    }
                }
            }
            for (i, (a, b)) in ws.grad.iter().zip(&fused).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{name}: staged grad[{i}] != fused");
            }
        }
    }

    #[test]
    fn init_is_seeded_and_finite() {
        let m = def("vgg11_mini");
        let a = m.init(0);
        let b = m.init(0);
        let c = m.init(1);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert!(a.iter().all(|v| v.is_finite()));
        assert_eq!(a.len(), m.param_count());
        // Biases at the head are zero.
        assert!(a[..m.classes].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn resnet_is_identity_at_init_in_blocks() {
        // With fc2 zero-init, block outputs equal relu(h_in + 0) = h_in
        // (h_in is already >= 0), so deep stacks don't blow up.
        let m = def("resnet34_mini");
        let p = m.init(0);
        let x = vec![0.1f32; 2 * m.feature_dim];
        let acts = m.forward(&p, &x, 2);
        let h0 = &acts.hs[0];
        let hl = acts.hs.last().unwrap();
        for (a, b) in h0.iter().zip(hl) {
            assert!((a - b).abs() < 1e-5, "block changed identity output");
        }
    }

    #[test]
    fn grad_stats_match_ref_py_golden() {
        // g = [1,2,3,4]: s=10 ss=30 mean=2.5 var=1.25 rms=sqrt(7.5).
        let (sigma, sigma2, l2) = normalized_grad_stats(&[1.0, 2.0, 3.0, 4.0]);
        let expect = (1.25f64.sqrt() / 7.5f64.sqrt()) as f32; // 0.408248...
        assert!((sigma - expect).abs() < 1e-6, "{sigma} vs {expect}");
        assert!((sigma2 - expect * expect).abs() < 1e-6);
        assert!((l2 - 30.0f32.sqrt()).abs() < 1e-5);
        // Constant vector: zero variance -> sigma 0.
        let (s0, _, _) = normalized_grad_stats(&[2.0; 8]);
        assert_eq!(s0, 0.0);
    }

    #[test]
    fn masked_rows_contribute_nothing() {
        let m = def("vgg11_mini");
        let p = m.init(3);
        let mut rng = crate::util::rng::Rng::new(5);
        let n_valid = 6;
        let x16: Vec<f32> = (0..n_valid * m.feature_dim).map(|_| rng.normal() as f32).collect();
        let y16: Vec<i32> = (0..n_valid).map(|_| rng.below(10) as i32).collect();

        let run = |bucket: usize| {
            let mut x = vec![0.0f32; bucket * m.feature_dim];
            let mut y = vec![0i32; bucket];
            let mut mask = vec![0.0f32; bucket];
            x[..x16.len()].copy_from_slice(&x16);
            y[..n_valid].copy_from_slice(&y16);
            mask[..n_valid].fill(1.0);
            let acts = m.forward(&p, &x, bucket);
            let lo = masked_ce_loss(&acts.logits, &y, &mask, bucket, m.classes);
            let g = m.backward(&p, &acts, &x, &lo.dlogits, bucket);
            (lo.loss, lo.acc, g)
        };
        let (l8, a8, g8) = run(8);
        let (l32, a32, g32) = run(32);
        assert!((l8 - l32).abs() < 1e-6, "loss depends on padding: {l8} vs {l32}");
        assert!((a8 - a32).abs() < 1e-6);
        for (a, b) in g8.iter().zip(&g32) {
            assert!((a - b).abs() < 1e-6, "gradient depends on padding");
        }
    }

    #[test]
    fn finite_difference_checks_vgg_gradient() {
        // Spot-check backward against central differences on a tiny batch.
        let m = def("vgg11_mini");
        let mut p = m.init(7);
        let mut rng = crate::util::rng::Rng::new(11);
        let batch = 4;
        let x: Vec<f32> = (0..batch * m.feature_dim).map(|_| rng.normal() as f32).collect();
        let y: Vec<i32> = (0..batch).map(|_| rng.below(10) as i32).collect();
        let mask = vec![1.0f32; batch];
        let loss_at = |p: &[f32]| {
            let acts = m.forward(p, &x, batch);
            masked_ce_loss(&acts.logits, &y, &mask, batch, m.classes).loss as f64
        };
        let acts = m.forward(&p, &x, batch);
        let lo = masked_ce_loss(&acts.logits, &y, &mask, batch, m.classes);
        let g = m.backward(&p, &acts, &x, &lo.dlogits, batch);
        // Probe a few parameters spread across the vector.
        let pc = m.param_count();
        for &idx in &[0usize, 11, pc / 3, pc / 2, pc - 5] {
            let eps = 1e-3f32;
            let orig = p[idx];
            p[idx] = orig + eps;
            let lp = loss_at(&p);
            p[idx] = orig - eps;
            let lm = loss_at(&p);
            p[idx] = orig;
            let fd = ((lp - lm) / (2.0 * eps as f64)) as f32;
            assert!(
                (fd - g[idx]).abs() < 2e-2 * (1.0 + fd.abs().max(g[idx].abs())),
                "param {idx}: fd {fd} vs analytic {}",
                g[idx]
            );
        }
    }

    #[test]
    fn finite_difference_checks_resnet_gradient() {
        let m = def("resnet34_mini");
        let mut p = m.init(9);
        // Perturb fc2 weights away from zero so the residual path is live.
        let mut rng = crate::util::rng::Rng::new(13);
        for v in p.iter_mut() {
            if *v == 0.0 {
                *v = (rng.normal() * 0.05) as f32;
            }
        }
        let batch = 3;
        let x: Vec<f32> = (0..batch * m.feature_dim).map(|_| rng.normal() as f32).collect();
        let y: Vec<i32> = (0..batch).map(|_| rng.below(100) as i32).collect();
        let mask = vec![1.0f32; batch];
        let loss_at = |p: &[f32]| {
            let acts = m.forward(p, &x, batch);
            masked_ce_loss(&acts.logits, &y, &mask, batch, m.classes).loss as f64
        };
        let acts = m.forward(&p, &x, batch);
        let lo = masked_ce_loss(&acts.logits, &y, &mask, batch, m.classes);
        let g = m.backward(&p, &acts, &x, &lo.dlogits, batch);
        let pc = m.param_count();
        for &idx in &[5usize, pc / 4, pc / 2, 3 * pc / 4, pc - 9] {
            let eps = 1e-3f32;
            let orig = p[idx];
            p[idx] = orig + eps;
            let lp = loss_at(&p);
            p[idx] = orig - eps;
            let lm = loss_at(&p);
            p[idx] = orig;
            let fd = ((lp - lm) / (2.0 * eps as f64)) as f32;
            assert!(
                (fd - g[idx]).abs() < 2e-2 * (1.0 + fd.abs().max(g[idx].abs())),
                "param {idx}: fd {fd} vs analytic {}",
                g[idx]
            );
        }
    }

    #[test]
    fn chained_backward_over_row_slices_is_bitwise_exact() {
        // The sharded data plane's core invariant: folding contiguous row
        // slices into a traveling gradient accumulator (in row order)
        // yields exactly the fused backward's bits, for ANY split — the
        // batch-dim reductions are sequential per output element.
        use super::super::exec::Pool;
        use super::super::workspace::Workspace;
        for name in ["vgg11_mini", "resnet34_mini"] {
            let m = def(name);
            let p = m.init(4);
            let mut rng = crate::util::rng::Rng::new(21);
            let rows = 11usize;
            let x: Vec<f32> = (0..rows * m.feature_dim).map(|_| rng.normal() as f32).collect();
            let y: Vec<i32> = (0..rows).map(|_| rng.below(m.classes) as i32).collect();
            let mask = vec![1.0f32; rows];
            let denom = rows as f32;

            let fused = {
                let acts = m.forward(&p, &x, rows);
                let lo = masked_ce_loss(&acts.logits, &y, &mask, rows, m.classes);
                m.backward(&p, &acts, &x, &lo.dlogits, rows)
            };

            for splits in [vec![11], vec![4, 7], vec![1, 1, 9], vec![3, 3, 3, 2]] {
                assert_eq!(splits.iter().sum::<usize>(), rows);
                let pool = Pool::sequential();
                let mut grad = vec![0.0f32; m.param_count()];
                let mut at = 0usize;
                for &c in &splits {
                    let (lo, hi) = (at, at + c);
                    at = hi;
                    let xs = &x[lo * m.feature_dim..hi * m.feature_dim];
                    let mut ws = Workspace::default();
                    m.forward_ws(&pool, &p, xs, c, &mut ws);
                    let (mut lt, mut cor) = (Vec::new(), Vec::new());
                    let logits = std::mem::take(&mut ws.logits);
                    let (mut lp, mut dl) = (Vec::new(), Vec::new());
                    masked_ce_rows(
                        &pool, &logits, &y[lo..hi], &mask[lo..hi], c, m.classes, denom,
                        &mut lp, &mut lt, &mut cor, &mut dl,
                    );
                    ws.logits = logits;
                    ws.dlogits = dl;
                    std::mem::swap(&mut ws.grad, &mut grad);
                    m.backward_acc_ws(&pool, &p, xs, c, &mut ws);
                    std::mem::swap(&mut ws.grad, &mut grad);
                }
                for (i, (a, b)) in grad.iter().zip(&fused).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{name} splits {splits:?}: grad[{i}] {a} != fused {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn rows_plus_fold_equals_fused_loss() {
        let m = def("vgg11_mini");
        let p = m.init(2);
        let mut rng = crate::util::rng::Rng::new(17);
        let rows = 9usize;
        let x: Vec<f32> = (0..rows * m.feature_dim).map(|_| rng.normal() as f32).collect();
        let y: Vec<i32> = (0..rows).map(|_| rng.below(10) as i32).collect();
        let mut mask = vec![1.0f32; rows];
        mask[rows - 1] = 0.0; // one padded row
        let acts = m.forward(&p, &x, rows);
        let fused = masked_ce_loss(&acts.logits, &y, &mask, rows, m.classes);
        // Shard the rows 4|5 and fold partials in order.
        // PARITY: full-mask fold, same association as the fused path above.
        let denom: f32 = mask.iter().sum::<f32>().max(1.0);
        let (mut lsum, mut asum) = (0.0f64, 0.0f64);
        for (lo, hi) in [(0usize, 4usize), (4, 9)] {
            let xs = &x[lo * m.feature_dim..hi * m.feature_dim];
            let acts_s = m.forward(&p, xs, hi - lo);
            let (mut lp, mut lt, mut cor, mut dl) = (Vec::new(), Vec::new(), Vec::new(), Vec::new());
            masked_ce_rows(
                &Pool::sequential(), &acts_s.logits, &y[lo..hi], &mask[lo..hi], hi - lo,
                m.classes, denom, &mut lp, &mut lt, &mut cor, &mut dl,
            );
            fold_masked_ce_partial(&lt, &cor, &mut lsum, &mut asum);
        }
        let loss = (lsum / denom as f64) as f32;
        let acc = (asum / denom as f64) as f32;
        assert_eq!(loss.to_bits(), fused.loss.to_bits(), "{loss} vs {}", fused.loss);
        assert_eq!(acc.to_bits(), fused.acc.to_bits());
    }

    #[test]
    fn adam_first_step_is_signed_lr() {
        // After one Adam step from zero state, m_hat = g and v_hat = g^2,
        // so every touched parameter moves by ~ -lr * sign(g).
        let g = [0.5f32, -2.0, 0.0, 1e-3];
        let mut s = OptState::adam(vec![1.0; 4]);
        apply_adam(&Pool::sequential(), &mut s, &g, 0.01);
        assert!((s.params[0] - (1.0 - 0.01)).abs() < 1e-4);
        assert!((s.params[1] - (1.0 + 0.01)).abs() < 1e-4);
        assert_eq!(s.params[2], 1.0);
        assert!((s.params[3] - (1.0 - 0.01)).abs() < 1e-3);
        assert_eq!(s.step, 1.0);
    }

    #[test]
    fn sgd_momentum_accumulates() {
        let g = [1.0f32];
        let mut s = OptState::new(vec![0.0], crate::config::Optimizer::Sgd);
        let seq = Pool::sequential();
        apply_sgd(&seq, &mut s, &g, 0.1);
        assert!((s.params[0] + 0.1).abs() < 1e-7); // -lr * 1
        apply_sgd(&seq, &mut s, &g, 0.1);
        // m = 0.9*1 + 1 = 1.9 -> total -0.1 - 0.19
        assert!((s.params[0] + 0.29).abs() < 1e-6);
    }
}
