//! Reusable scratch buffers for the native backend's hot paths.
//!
//! Every intermediate tensor of a train/eval/policy-update step lives in a
//! [`Workspace`]; buffers are `clear()+resize()`d to the step's shape, so
//! after one warmup step per (model, bucket) the capacities stabilize and
//! steady-state steps perform **zero heap allocations**. A
//! [`WorkspacePool`] keeps finished workspaces behind a mutex so the
//! backend stays `&self` + `Send + Sync`: concurrent callers each pop
//! their own workspace (the pool grows to the peak concurrency and then
//! stops allocating).
//!
//! The allocation regression test keys off [`Workspace::capacity_bytes`]:
//! if a code change starts allocating per step, the pooled capacity keeps
//! growing after warmup and the test fails.

use std::sync::Mutex;

/// Scratch buffers for one in-flight backend call. Field groups:
/// model train/eval (`hs`/`us`/`logits`/... ) and PPO update (`p_*`).
#[derive(Default)]
pub struct Workspace {
    /// Post-ReLU activations: VGG — one per layer; ResNet — stem output
    /// followed by every block output (`depth + 1` entries).
    pub hs: Vec<Vec<f32>>,
    /// ResNet only: post-ReLU inner activations, one per block.
    pub us: Vec<Vec<f32>>,
    pub logits: Vec<f32>,
    pub logp: Vec<f32>,
    pub dlogits: Vec<f32>,
    pub correct: Vec<f32>,
    /// Per-row loss terms (`-logp[y]*mask`) feeding the row-order fold.
    pub loss_terms: Vec<f32>,
    pub grad: Vec<f32>,
    /// Backward row-gradient buffer (ping-ponged with `dtmp`).
    pub dh: Vec<f32>,
    /// ResNet inner-path gradient buffer.
    pub du: Vec<f32>,
    /// Scratch target for the next layer's input gradient.
    pub dtmp: Vec<f32>,

    // --- PPO policy update ---
    pub p_h1: Vec<f32>,
    pub p_h2: Vec<f32>,
    pub p_logits: Vec<f32>,
    pub p_values: Vec<f32>,
    pub p_logp: Vec<f32>,
    pub p_dlogits: Vec<f32>,
    pub p_dvalues: Vec<f32>,
    pub p_grad: Vec<f32>,
    pub p_dh1: Vec<f32>,
    pub p_dh2: Vec<f32>,
}

impl Workspace {
    /// Ensure `v` has at least `n` slot vectors (keeps existing capacity).
    pub fn ensure_slots(v: &mut Vec<Vec<f32>>, n: usize) {
        while v.len() < n {
            v.push(Vec::new());
        }
    }

    /// Total heap bytes currently reserved by this workspace.
    pub fn capacity_bytes(&self) -> usize {
        let nested = |vv: &Vec<Vec<f32>>| -> usize {
            vv.capacity() * std::mem::size_of::<Vec<f32>>()
                + vv.iter().map(|v| v.capacity() * 4).sum::<usize>()
        };
        let flat = [
            &self.logits,
            &self.logp,
            &self.dlogits,
            &self.correct,
            &self.loss_terms,
            &self.grad,
            &self.dh,
            &self.du,
            &self.dtmp,
            &self.p_h1,
            &self.p_h2,
            &self.p_logits,
            &self.p_values,
            &self.p_logp,
            &self.p_dlogits,
            &self.p_dvalues,
            &self.p_grad,
            &self.p_dh1,
            &self.p_dh2,
        ];
        nested(&self.hs)
            + nested(&self.us)
            + flat.iter().map(|v| v.capacity() * 4).sum::<usize>()
    }
}

/// Lock-guarded free list of workspaces. `take` pops (or creates) one;
/// `put` returns it for reuse. The lock is held only for the push/pop.
#[derive(Default)]
pub struct WorkspacePool {
    slots: Mutex<Vec<Workspace>>,
}

impl WorkspacePool {
    pub fn take(&self) -> Workspace {
        self.slots.lock().unwrap().pop().unwrap_or_default()
    }

    pub fn put(&self, ws: Workspace) {
        self.slots.lock().unwrap().push(ws);
    }

    /// (workspace count, total reserved bytes) — the allocation regression
    /// probe: both must be flat across steady-state steps.
    pub fn stats(&self) -> (usize, usize) {
        let slots = self.slots.lock().unwrap();
        (
            slots.len(),
            slots.iter().map(|w| w.capacity_bytes()).sum(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_recycles_instead_of_allocating() {
        let pool = WorkspacePool::default();
        let mut ws = pool.take();
        ws.grad.resize(1000, 0.0);
        let bytes = ws.capacity_bytes();
        assert!(bytes >= 4000);
        pool.put(ws);
        assert_eq!(pool.stats().0, 1);
        assert_eq!(pool.stats().1, bytes);
        // Take it back: same buffer, capacity intact.
        let ws = pool.take();
        assert_eq!(ws.capacity_bytes(), bytes);
        assert_eq!(pool.stats().0, 0);
        pool.put(ws);
    }

    #[test]
    fn capacity_counts_nested_activations() {
        let mut ws = Workspace::default();
        Workspace::ensure_slots(&mut ws.hs, 3);
        ws.hs[0].resize(100, 0.0);
        assert!(ws.capacity_bytes() >= 400);
    }
}
