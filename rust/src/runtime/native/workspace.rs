//! Reusable scratch buffers for the native backend's hot paths.
//!
//! Every intermediate tensor of a train/eval/policy-update step lives in a
//! [`Workspace`]; buffers are `clear()+resize()`d to the step's shape, so
//! after one warmup step per (model, bucket) the capacities stabilize and
//! steady-state steps perform **zero heap allocations**. A
//! [`WorkspacePool`] keeps finished workspaces behind a mutex so the
//! backend stays `&self` + `Send + Sync`: concurrent callers each pop
//! their own workspace (the pool grows to the peak concurrency and then
//! stops allocating).
//!
//! ## Generation-tagged packed panels
//!
//! [`PanelCache`] holds k-major packed transposes of weight matrices for
//! the streaming `matmul_bt` path (see `linalg::matmul_bt_ws`). Entries
//! are keyed by the layer's weight offset and tagged with the workspace's
//! **step generation** — a process-unique id assigned by
//! [`Workspace::begin_step`] at the start of every train/eval/policy/shard
//! step. Parameters change between steps (optimizer updates), so a panel
//! is valid only while its generation matches: within one step it is
//! packed once and reused for every use (the fwd/bwd pair of that step);
//! the next step's `begin_step` bump invalidates it wholesale. This makes
//! stale reuse impossible no matter how callers mutate their `OptState`
//! between calls.
//!
//! The allocation regression test keys off [`Workspace::capacity_bytes`]:
//! if a code change starts allocating per step, the pooled capacity keeps
//! growing after warmup and the test fails.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Process-wide step-generation counter ([`Workspace::begin_step`]).
static STEP_GEN: AtomicU64 = AtomicU64::new(0);

/// One cached k-major packed panel: the `[N, K]` transpose of a `[K, N]`
/// weight matrix, valid for exactly one step generation.
struct PanelEntry {
    /// Layer identity: the weight's offset in the flat parameter vector.
    key: usize,
    k: usize,
    n: usize,
    /// Step generation the panel was packed under.
    gen: u64,
    wt: Vec<f32>,
}

/// Generation-tagged panel store. Entries are few (one per dense layer of
/// the model in flight) and looked up linearly; buffers are recycled
/// across generations so steady-state packing allocates nothing.
#[derive(Default)]
pub struct PanelCache {
    entries: Vec<PanelEntry>,
}

impl PanelCache {
    /// The panel buffer for `(key, gen, k, n)` plus whether the caller
    /// must (re)pack it: `true` when no current-generation panel exists
    /// (first use this step, or the entry is stale from an earlier
    /// generation — its buffer is reused, its contents are not).
    pub fn slot(&mut self, key: usize, gen: u64, k: usize, n: usize) -> (&mut Vec<f32>, bool) {
        if let Some(idx) = self.entries.iter().position(|e| e.key == key) {
            let e = &mut self.entries[idx];
            let fresh = !(e.gen == gen && e.k == k && e.n == n);
            e.gen = gen;
            e.k = k;
            e.n = n;
            return (&mut e.wt, fresh);
        }
        self.entries.push(PanelEntry {
            key,
            k,
            n,
            gen,
            wt: Vec::new(),
        });
        let e = self.entries.last_mut().expect("just pushed");
        (&mut e.wt, true)
    }

    /// Total heap bytes reserved by the cached panels.
    pub fn capacity_bytes(&self) -> usize {
        self.entries.capacity() * std::mem::size_of::<PanelEntry>()
            + self.entries.iter().map(|e| e.wt.capacity() * 4).sum::<usize>()
    }
}

/// Scratch buffers for one in-flight backend call. Field groups:
/// model train/eval (`hs`/`us`/`logits`/... ) and PPO update (`p_*`).
#[derive(Default)]
pub struct Workspace {
    /// Step generation of the call in flight (see [`Workspace::begin_step`]).
    pub gen: u64,
    /// Generation-tagged packed weight panels for the streaming
    /// `matmul_bt` path.
    pub panels: PanelCache,
    /// Post-ReLU activations: VGG — one per layer; ResNet — stem output
    /// followed by every block output (`depth + 1` entries).
    pub hs: Vec<Vec<f32>>,
    /// ResNet only: post-ReLU inner activations, one per block.
    pub us: Vec<Vec<f32>>,
    pub logits: Vec<f32>,
    pub logp: Vec<f32>,
    pub dlogits: Vec<f32>,
    pub correct: Vec<f32>,
    /// Per-row loss terms (`-logp[y]*mask`) feeding the row-order fold.
    pub loss_terms: Vec<f32>,
    pub grad: Vec<f32>,
    /// Backward row-gradient buffer (ping-ponged with `dtmp`).
    pub dh: Vec<f32>,
    /// ResNet inner-path gradient buffer.
    pub du: Vec<f32>,
    /// Scratch target for the next layer's input gradient.
    pub dtmp: Vec<f32>,

    // --- PPO policy update ---
    pub p_h1: Vec<f32>,
    pub p_h2: Vec<f32>,
    pub p_logits: Vec<f32>,
    pub p_values: Vec<f32>,
    pub p_logp: Vec<f32>,
    pub p_dlogits: Vec<f32>,
    pub p_dvalues: Vec<f32>,
    pub p_grad: Vec<f32>,
    pub p_dh1: Vec<f32>,
    pub p_dh2: Vec<f32>,
}

impl Workspace {
    /// Start a new step: assign this workspace a process-unique
    /// generation, invalidating every cached panel from earlier steps.
    /// Called once per train/eval/policy-update/shard step — a shard
    /// step's forward and backward halves share one generation (the
    /// `ShardCtx` retains the workspace between them). Returns the new
    /// generation for threading into the packed-panel kernels.
    pub fn begin_step(&mut self) -> u64 {
        self.gen = STEP_GEN.fetch_add(1, Ordering::Relaxed) + 1;
        self.gen
    }

    /// Ensure `v` has at least `n` slot vectors (keeps existing capacity).
    pub fn ensure_slots(v: &mut Vec<Vec<f32>>, n: usize) {
        while v.len() < n {
            v.push(Vec::new());
        }
    }

    /// Total heap bytes currently reserved by this workspace.
    pub fn capacity_bytes(&self) -> usize {
        let nested = |vv: &Vec<Vec<f32>>| -> usize {
            vv.capacity() * std::mem::size_of::<Vec<f32>>()
                + vv.iter().map(|v| v.capacity() * 4).sum::<usize>()
        };
        let flat = [
            &self.logits,
            &self.logp,
            &self.dlogits,
            &self.correct,
            &self.loss_terms,
            &self.grad,
            &self.dh,
            &self.du,
            &self.dtmp,
            &self.p_h1,
            &self.p_h2,
            &self.p_logits,
            &self.p_values,
            &self.p_logp,
            &self.p_dlogits,
            &self.p_dvalues,
            &self.p_grad,
            &self.p_dh1,
            &self.p_dh2,
        ];
        nested(&self.hs)
            + nested(&self.us)
            + self.panels.capacity_bytes()
            + flat.iter().map(|v| v.capacity() * 4).sum::<usize>()
    }
}

/// Reusable decode/fold buffers for the compressed gradient wire: one
/// per ring endpoint, reused hop after hop so the steady-state slice
/// path (decode → fold → re-encode) allocates nothing. Capacities only
/// grow, and only until the largest window has passed through once —
/// the zero-allocation regression test pins `capacity_bytes` flat.
#[derive(Default)]
pub struct WireScratch {
    /// Decoded dense window (topk/q8 hop payloads land here).
    pub dense: Vec<f32>,
    /// The folded window under construction for the reply frame.
    pub fold: Vec<f32>,
    /// Index scratch for the top-k partial select.
    pub order: Vec<u32>,
}

impl WireScratch {
    /// Total reserved bytes across the scratch buffers (the allocation
    /// regression probe).
    pub fn capacity_bytes(&self) -> usize {
        self.dense.capacity() * 4 + self.fold.capacity() * 4 + self.order.capacity() * 4
    }
}

/// Lock-guarded free list of workspaces. `take` pops (or creates) one;
/// `put` returns it for reuse. The lock is held only for the push/pop.
#[derive(Default)]
pub struct WorkspacePool {
    slots: Mutex<Vec<Workspace>>,
}

impl WorkspacePool {
    pub fn take(&self) -> Workspace {
        self.slots.lock().unwrap().pop().unwrap_or_default()
    }

    pub fn put(&self, ws: Workspace) {
        self.slots.lock().unwrap().push(ws);
    }

    /// (workspace count, total reserved bytes) — the allocation regression
    /// probe: both must be flat across steady-state steps.
    pub fn stats(&self) -> (usize, usize) {
        let slots = self.slots.lock().unwrap();
        (
            slots.len(),
            slots.iter().map(|w| w.capacity_bytes()).sum(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_recycles_instead_of_allocating() {
        let pool = WorkspacePool::default();
        let mut ws = pool.take();
        ws.grad.resize(1000, 0.0);
        let bytes = ws.capacity_bytes();
        assert!(bytes >= 4000);
        pool.put(ws);
        assert_eq!(pool.stats().0, 1);
        assert_eq!(pool.stats().1, bytes);
        // Take it back: same buffer, capacity intact.
        let ws = pool.take();
        assert_eq!(ws.capacity_bytes(), bytes);
        assert_eq!(pool.stats().0, 0);
        pool.put(ws);
    }

    #[test]
    fn capacity_counts_nested_activations() {
        let mut ws = Workspace::default();
        Workspace::ensure_slots(&mut ws.hs, 3);
        ws.hs[0].resize(100, 0.0);
        assert!(ws.capacity_bytes() >= 400);
    }

    #[test]
    fn begin_step_generations_are_unique_and_monotone() {
        let mut a = Workspace::default();
        let mut b = Workspace::default();
        assert_eq!(a.gen, 0, "fresh workspaces start at the never-valid gen 0");
        let g1 = a.begin_step();
        let g2 = b.begin_step();
        let g3 = a.begin_step();
        assert!(g1 > 0 && g2 > g1 && g3 > g2);
        assert_eq!(a.gen, g3);
    }

    #[test]
    fn panel_slot_reuses_buffer_and_tracks_staleness() {
        let mut cache = PanelCache::default();
        {
            let (wt, fresh) = cache.slot(7, 1, 4, 3);
            assert!(fresh, "first use must pack");
            wt.resize(12, 1.0);
        }
        // Same key + generation: valid, no repack.
        let (_, fresh) = cache.slot(7, 1, 4, 3);
        assert!(!fresh);
        // Generation bump: stale — buffer reused, contents must be
        // repacked.
        {
            let (wt, fresh) = cache.slot(7, 2, 4, 3);
            assert!(fresh, "a generation bump invalidates the panel");
            assert_eq!(wt.len(), 12, "buffer is recycled, not reallocated");
        }
        // A second layer gets its own entry.
        let (_, fresh) = cache.slot(99, 2, 2, 2);
        assert!(fresh);
        assert!(cache.capacity_bytes() >= 12 * 4);
    }
}
