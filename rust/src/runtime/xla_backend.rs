//! [`ComputeBackend`] implementation over the PJRT [`ArtifactStore`]
//! (`backend-xla` feature).
//!
//! Thin adapter: flat `f32` state crosses the trait as slices and is
//! wrapped into literals per call. On the CPU PJRT plugin "device" memory
//! is host memory, so this costs one memcpy per argument — negligible
//! against the train-step compute (measured in EXPERIMENTS.md §Perf; the
//! buffer-resident alternative is documented in DESIGN.md §Perf).

use super::backend::{
    ComputeBackend, OptState, PolicyOut, PpoHyper, PpoMinibatch, PpoStats, Schema, TrainOut,
};
use super::store::ArtifactStore;
use super::{lit_f32, lit_i32, lit_scalar1};
use crate::config::{Optimizer, PpoVariant};
use std::path::Path;

pub struct XlaBackend {
    store: ArtifactStore,
    schema: Schema,
}

impl XlaBackend {
    pub fn open(dir: &Path) -> anyhow::Result<Self> {
        let store = ArtifactStore::open(dir)?;
        let m = &store.manifest;
        let schema = Schema {
            buckets: m.buckets.clone(),
            eval_batch: m.eval_batch,
            state_dim: m.state_dim,
            n_actions: m.n_actions,
            max_workers: m.max_workers,
            ppo_minibatch: m.ppo_minibatch,
            feature_dim: m.feature_dim,
            policy_param_count: m.policy_param_count,
            models: m.models.clone(),
        };
        Ok(XlaBackend { store, schema })
    }

    pub fn open_default() -> anyhow::Result<Self> {
        Self::open(&super::manifest::default_artifacts_dir())
    }

    pub fn store(&self) -> &ArtifactStore {
        &self.store
    }
}

impl ComputeBackend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn schema(&self) -> &Schema {
        &self.schema
    }

    fn init_params(&self, model: &str, seed: u64) -> anyhow::Result<Vec<f32>> {
        self.store.manifest.load_init_params(model, seed)
    }

    fn init_policy(&self, seed: u64) -> anyhow::Result<Vec<f32>> {
        self.store.manifest.load_init_policy(seed)
    }

    fn policy_forward(&self, theta: &[f32], states: &[f32]) -> anyhow::Result<PolicyOut> {
        let pc = self.schema.policy_param_count;
        anyhow::ensure!(theta.len() == pc, "theta len {} != {pc}", theta.len());
        let theta_l = lit_f32(theta, &[pc as i64])?;
        let states_l = lit_f32(
            states,
            &[self.schema.max_workers as i64, self.schema.state_dim as i64],
        )?;
        let out = self.store.run("policy_forward", &[&theta_l, &states_l])?;
        Ok(PolicyOut {
            logp: out.vec_f32(0)?,
            values: out.vec_f32(1)?,
        })
    }

    fn policy_update(
        &self,
        variant: PpoVariant,
        opt: &mut OptState,
        mb: &PpoMinibatch,
        hp: PpoHyper,
    ) -> anyhow::Result<PpoStats> {
        let artifact = match variant {
            PpoVariant::Clipped => "policy_update",
            PpoVariant::Simplified => "policy_update_simple",
        };
        let pc = self.schema.policy_param_count;
        let b = mb.mask.len() as i64;
        let sd = self.schema.state_dim as i64;
        let out = self.store.run(
            artifact,
            &[
                &lit_f32(&opt.params, &[pc as i64])?,
                &lit_f32(&opt.m, &[pc as i64])?,
                &lit_f32(&opt.v, &[pc as i64])?,
                &lit_scalar1(opt.step),
                &lit_f32(mb.states, &[b, sd])?,
                &lit_i32(mb.actions, &[b])?,
                &lit_f32(mb.old_logp, &[b])?,
                &lit_f32(mb.advantages, &[b])?,
                &lit_f32(mb.returns, &[b])?,
                &lit_f32(mb.mask, &[b])?,
                &lit_scalar1(hp.lr),
                &lit_scalar1(hp.clip_eps),
                &lit_scalar1(hp.ent_coef),
                &lit_scalar1(hp.vf_coef),
            ],
        )?;
        let stats = PpoStats {
            loss: out.scalar_f32(4)?,
            pg_loss: out.scalar_f32(5)?,
            v_loss: out.scalar_f32(6)?,
            entropy: out.scalar_f32(7)?,
            approx_kl: out.scalar_f32(8)?,
        };
        opt.params = out.vec_f32(0)?;
        opt.m = out.vec_f32(1)?;
        opt.v = out.vec_f32(2)?;
        opt.step = out.scalar_f32(3)?;
        Ok(stats)
    }

    fn train_step(
        &self,
        model: &str,
        optimizer: Optimizer,
        bucket: usize,
        state: &mut OptState,
        x: &[f32],
        y: &[i32],
        mask: &[f32],
        lr: f32,
    ) -> anyhow::Result<TrainOut> {
        let name = self
            .store
            .manifest
            .train_artifact(model, optimizer.as_str(), bucket);
        let pc = state.params.len() as i64;
        let fd = self.schema.feature_dim as i64;
        let b = bucket as i64;
        let out = self.store.run(
            &name,
            &[
                &lit_f32(&state.params, &[pc])?,
                &lit_f32(&state.m, &[state.m.len() as i64])?,
                &lit_f32(&state.v, &[state.v.len() as i64])?,
                &lit_scalar1(state.step),
                &lit_f32(x, &[b, fd])?,
                &lit_i32(y, &[b])?,
                &lit_f32(mask, &[b])?,
                &lit_scalar1(lr),
            ],
        )?;
        let metrics = TrainOut {
            loss: out.scalar_f32(4)?,
            acc: out.scalar_f32(5)?,
            correct: out.vec_f32(6)?,
            sigma_norm: out.scalar_f32(7)?,
            sigma_norm2: out.scalar_f32(8)?,
            grad_l2: out.scalar_f32(9)?,
        };
        state.params = out.vec_f32(0)?;
        state.m = out.vec_f32(1)?;
        state.v = out.vec_f32(2)?;
        state.step = out.scalar_f32(3)?;
        Ok(metrics)
    }

    fn eval_step(
        &self,
        model: &str,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        mask: &[f32],
    ) -> anyhow::Result<(f32, f32)> {
        let name = self.store.manifest.eval_artifact(model);
        let m = mask.len() as i64;
        let fd = self.schema.feature_dim as i64;
        let out = self.store.run(
            &name,
            &[
                &lit_f32(params, &[params.len() as i64])?,
                &lit_f32(x, &[m, fd])?,
                &lit_i32(y, &[m])?,
                &lit_f32(mask, &[m])?,
            ],
        )?;
        Ok((out.scalar_f32(0)?, out.scalar_f32(1)?))
    }

    fn compiled_count(&self) -> usize {
        self.store.compiled_count()
    }

    fn compile_log(&self) -> Vec<(String, f64)> {
        self.store.compile_log()
    }
}
