//! The [`ComputeBackend`] trait: the artifact contract as a Rust seam.
//!
//! Captures exactly the manifest's executable surface — `policy_forward`,
//! `policy_update` / `policy_update_simple`, the `train_{model}_{opt}_{bucket}`
//! ladder, `eval_{model}`, and the seeded init snapshots — as trait methods
//! over flat `f32` buffers. Backends own the math; callers own the state
//! ([`OptState`] is passed `&mut` so parameters never cross the trait twice).
//!
//! All tensors are row-major flat slices; shapes are implied by the
//! [`Schema`] (the native equivalent of `manifest.json`).

use crate::config::{Optimizer, PpoVariant};
use std::collections::BTreeMap;
use std::sync::Arc;

pub use super::manifest::ModelInfo;

/// Static I/O schema shared by every backend — the native twin of the
/// manifest header. Sizing information only; no artifact file references.
#[derive(Clone, Debug)]
pub struct Schema {
    /// Batch-bucket ladder (sorted ascending; XLA shapes are static, so
    /// dynamic batch sizes round up to the smallest bucket >= B).
    pub buckets: Vec<usize>,
    pub eval_batch: usize,
    pub state_dim: usize,
    pub n_actions: usize,
    pub max_workers: usize,
    pub ppo_minibatch: usize,
    pub feature_dim: usize,
    pub policy_param_count: usize,
    pub models: BTreeMap<String, ModelInfo>,
}

impl Schema {
    /// Smallest bucket >= n, or an error if n exceeds the ladder.
    pub fn bucket_for(&self, n: usize) -> anyhow::Result<usize> {
        self.buckets.iter().copied().find(|&b| b >= n).ok_or_else(|| {
            anyhow::anyhow!(
                "batch {n} exceeds largest bucket {}",
                self.buckets.last().copied().unwrap_or(0)
            )
        })
    }

    pub fn model(&self, name: &str) -> anyhow::Result<&ModelInfo> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown model {name:?}"))
    }
}

/// Flat model/optimizer state threaded through train and policy updates.
/// `m` is the SGD momentum buffer or the Adam first moment; `v` is the Adam
/// second moment (length 1 dummy for SGD, mirroring the artifact signature).
#[derive(Clone, Debug)]
pub struct OptState {
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: f32,
}

impl OptState {
    /// Fresh optimizer state for `params` under `optimizer`.
    pub fn new(params: Vec<f32>, optimizer: Optimizer) -> Self {
        let pc = params.len();
        let v_len = match optimizer {
            Optimizer::Adam => pc,
            Optimizer::Sgd => 1,
        };
        OptState {
            params,
            m: vec![0.0; pc],
            v: vec![0.0; v_len],
            step: 0.0,
        }
    }

    /// Adam state (the policy optimizer is always Adam).
    pub fn adam(params: Vec<f32>) -> Self {
        Self::new(params, Optimizer::Adam)
    }

    /// Reset optimizer moments and the step counter, keeping `params`.
    pub fn reset_moments(&mut self) {
        self.m.iter_mut().for_each(|x| *x = 0.0);
        self.v.iter_mut().for_each(|x| *x = 0.0);
        self.step = 0.0;
    }
}

/// Outputs of one fused train step (signature mirror of the AOT artifact:
/// params/m/v/step are updated in the caller's [`OptState`]).
/// Reusable: pass `&mut TrainOut` to
/// [`ComputeBackend::train_step_into`] and `correct`'s buffer is recycled
/// across steps.
#[derive(Clone, Debug, Default)]
pub struct TrainOut {
    pub loss: f32,
    pub acc: f32,
    /// Per-sample masked correctness, length = bucket.
    pub correct: Vec<f32>,
    pub sigma_norm: f32,
    pub sigma_norm2: f32,
    pub grad_l2: f32,
}

/// Outputs of one policy forward pass over all `max_workers` padded rows.
#[derive(Clone, Debug)]
pub struct PolicyOut {
    /// Log-probabilities, row-major `[max_workers, n_actions]`.
    pub logp: Vec<f32>,
    /// Value estimates, length `max_workers`.
    pub values: Vec<f32>,
}

/// Scalar diagnostics of one PPO minibatch step.
#[derive(Clone, Copy, Debug, Default)]
pub struct PpoStats {
    pub loss: f32,
    pub pg_loss: f32,
    pub v_loss: f32,
    pub entropy: f32,
    pub approx_kl: f32,
}

/// One padded+masked PPO minibatch (all slices length `ppo_minibatch`,
/// `states` length `ppo_minibatch * state_dim`).
#[derive(Clone, Copy, Debug)]
pub struct PpoMinibatch<'a> {
    pub states: &'a [f32],
    pub actions: &'a [i32],
    pub old_logp: &'a [f32],
    pub advantages: &'a [f32],
    pub returns: &'a [f32],
    pub mask: &'a [f32],
}

/// PPO update hyperparameters (the artifact's scalar inputs).
#[derive(Clone, Copy, Debug)]
pub struct PpoHyper {
    pub lr: f32,
    pub clip_eps: f32,
    pub ent_coef: f32,
    pub vf_coef: f32,
}

/// The compute seam. Object-safe; implementations must be shareable across
/// threads (the distributed demo drives one backend per process thread).
pub trait ComputeBackend: Send + Sync {
    /// Short identifier ("native", "xla") for logs and the CLI.
    fn name(&self) -> &'static str;

    /// Static sizing/shape information.
    fn schema(&self) -> &Schema;

    /// Seeded initial parameters for a zoo model (flat, ravel_pytree order).
    fn init_params(&self, model: &str, seed: u64) -> anyhow::Result<Vec<f32>>;

    /// Seeded initial policy parameters.
    fn init_policy(&self, seed: u64) -> anyhow::Result<Vec<f32>>;

    /// `policy_forward`: score `max_workers` padded state rows in one call.
    /// `states` is `[max_workers, state_dim]` row-major.
    fn policy_forward(&self, theta: &[f32], states: &[f32]) -> anyhow::Result<PolicyOut>;

    /// One PPO minibatch step (`policy_update` / `policy_update_simple`),
    /// updating `opt` (theta + Adam moments) in place.
    fn policy_update(
        &self,
        variant: PpoVariant,
        opt: &mut OptState,
        mb: &PpoMinibatch,
        hp: PpoHyper,
    ) -> anyhow::Result<PpoStats>;

    /// One fused train step at `bucket` (`train_{model}_{opt}_b{bucket}`),
    /// updating `state` in place. `x` is `[bucket, feature_dim]`, `y`/`mask`
    /// length `bucket`; padded rows carry mask 0.
    #[allow(clippy::too_many_arguments)]
    fn train_step(
        &self,
        model: &str,
        optimizer: Optimizer,
        bucket: usize,
        state: &mut OptState,
        x: &[f32],
        y: &[i32],
        mask: &[f32],
        lr: f32,
    ) -> anyhow::Result<TrainOut>;

    /// Buffer-reusing variant of [`ComputeBackend::train_step`]: writes
    /// into `out` instead of returning a fresh `TrainOut`, so steady-state
    /// callers allocate nothing. Default implementation delegates to
    /// `train_step`; the native backend overrides it with the real
    /// (workspace-pooled, zero-allocation) path.
    #[allow(clippy::too_many_arguments)]
    fn train_step_into(
        &self,
        model: &str,
        optimizer: Optimizer,
        bucket: usize,
        state: &mut OptState,
        x: &[f32],
        y: &[i32],
        mask: &[f32],
        lr: f32,
        out: &mut TrainOut,
    ) -> anyhow::Result<()> {
        *out = self.train_step(model, optimizer, bucket, state, x, y, mask, lr)?;
        Ok(())
    }

    /// Held-out evaluation (`eval_{model}`): returns (loss, acc).
    fn eval_step(
        &self,
        model: &str,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        mask: &[f32],
    ) -> anyhow::Result<(f32, f32)>;

    /// Executables compiled so far (0 for backends that don't compile).
    fn compiled_count(&self) -> usize {
        0
    }

    /// (artifact, compile_seconds) log for the overhead study.
    fn compile_log(&self) -> Vec<(String, f64)> {
        Vec::new()
    }

    // --- data-plane sharding (no-ops for single-process backends) ---

    /// Number of data-plane shards a fused batch fans out across (1 for
    /// single-process backends).
    fn shard_count(&self) -> usize {
        1
    }

    /// Active-shard mask, length [`ComputeBackend::shard_count`]. Inactive
    /// shards hold no rows; their samples redistribute across survivors.
    fn shard_membership(&self) -> Vec<bool> {
        vec![true; self.shard_count()]
    }

    /// Mark one shard active/inactive; row assignment rebalances from the
    /// next step on. Returns false when unsupported, out of range, a
    /// no-op, or refused (the last active shard can never be dropped) —
    /// membership changes never change the math, only who computes what.
    fn set_shard_active(&self, _shard: usize, _active: bool) -> bool {
        false
    }
}

/// Shared handle to a backend.
pub type Backend = Arc<dyn ComputeBackend>;

/// A fresh native backend handle (always available; used by tests that pin
/// behaviour to the pure-Rust path regardless of `DYNAMIX_BACKEND`).
pub fn native_backend() -> Backend {
    Arc::new(super::native::NativeBackend::new())
}

/// A sharded loopback data plane over `n` in-process worker shards (see
/// [`crate::runtime::sharded::ShardedBackend`]). Bit-identical to the
/// native backend on every fused batch.
pub fn sharded_backend(n: usize) -> Backend {
    Arc::new(super::sharded::ShardedBackend::loopback(n))
}

/// Select a backend from `DYNAMIX_BACKEND` (`native` | `sharded` | `xla` |
/// `auto`).
///
/// `sharded` splits every fused batch across `DYNAMIX_SHARDS` (default 2)
/// loopback worker shards with a chained deterministic gradient reduction.
/// `auto` (or unset): the XLA backend when it is compiled in *and* the
/// artifacts directory exists; the native backend otherwise — so a fresh
/// clone works with zero setup and `make artifacts` upgrades in place.
pub fn default_backend() -> anyhow::Result<Backend> {
    let choice = crate::config::env::backend_choice();
    match choice.as_str() {
        "native" => Ok(native_backend()),
        "sharded" => {
            let n = crate::config::env::shards().unwrap_or(2);
            Ok(sharded_backend(n))
        }
        "xla" => open_xla(),
        "" | "auto" => {
            if cfg!(feature = "backend-xla") && artifacts_present() {
                open_xla()
            } else {
                Ok(native_backend())
            }
        }
        other => anyhow::bail!("unknown DYNAMIX_BACKEND {other:?} (native|sharded|xla|auto)"),
    }
}

/// Apply a config-file kernel-tier request: sets `DYNAMIX_KERNEL` when
/// the environment hasn't picked one (the env always wins). Must run
/// before the first backend is constructed — the process-global pool
/// reads the variable exactly once; a later call is a silent no-op on the
/// already-initialized pool.
pub fn apply_kernel_request(kernel: Option<&str>) {
    if let Some(k) = kernel {
        crate::config::env::request_kernel(k);
    }
}

/// Apply a config-file slice-codec request: sets `DYNAMIX_WIRE` when the
/// environment hasn't picked one (the env always wins). Must run before
/// the backend/trainer constructions that read the variable once.
pub fn apply_wire_request(wire: Option<&str>) {
    if let Some(w) = wire {
        crate::config::env::request_wire(w);
    }
}

/// Backend honoring an explicit shard request from config/CLI: when
/// `DYNAMIX_BACKEND` is unset and `shards` is `Some(n)`, a loopback
/// sharded data plane; otherwise the environment selection wins.
pub fn backend_for(shards: Option<usize>) -> anyhow::Result<Backend> {
    if crate::config::env::backend_choice().is_empty() {
        if let Some(n) = shards {
            return Ok(sharded_backend(n));
        }
    }
    default_backend()
}

fn artifacts_present() -> bool {
    super::manifest::default_artifacts_dir()
        .join("manifest.json")
        .exists()
}

#[cfg(feature = "backend-xla")]
fn open_xla() -> anyhow::Result<Backend> {
    Ok(Arc::new(super::xla_backend::XlaBackend::open_default()?))
}

#[cfg(not(feature = "backend-xla"))]
fn open_xla() -> anyhow::Result<Backend> {
    anyhow::bail!(
        "DYNAMIX_BACKEND=xla requested but this build has no `backend-xla` \
         feature; uncomment the `xla` dependency in rust/Cargo.toml, rebuild \
         with `--features backend-xla`, and run `make artifacts` (see README)"
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schema_bucket_for_picks_smallest_upper() {
        let s = crate::runtime::native::NativeBackend::new();
        let m = s.schema();
        assert_eq!(m.bucket_for(1).unwrap(), 32);
        assert_eq!(m.bucket_for(32).unwrap(), 32);
        assert_eq!(m.bucket_for(33).unwrap(), 64);
        let &last = m.buckets.last().unwrap();
        assert_eq!(m.bucket_for(last).unwrap(), last);
        assert!(m.bucket_for(last + 1).is_err());
    }

    #[test]
    fn default_backend_env_override() {
        // `native` always resolves; garbage never does. (Run serially with
        // env juggling to avoid cross-test races on the var.)
        let prev = std::env::var("DYNAMIX_BACKEND").ok(); // lint:allow(env-read): test saves/restores the raw variable around the override.
        std::env::set_var("DYNAMIX_BACKEND", "native");
        assert_eq!(default_backend().unwrap().name(), "native");
        std::env::set_var("DYNAMIX_BACKEND", "bogus");
        assert!(default_backend().is_err());
        match prev {
            Some(v) => std::env::set_var("DYNAMIX_BACKEND", v),
            None => std::env::remove_var("DYNAMIX_BACKEND"),
        }
    }

    #[test]
    fn opt_state_shapes_follow_optimizer() {
        let s = OptState::new(vec![0.0; 10], Optimizer::Sgd);
        assert_eq!((s.m.len(), s.v.len()), (10, 1));
        let a = OptState::new(vec![0.0; 10], Optimizer::Adam);
        assert_eq!((a.m.len(), a.v.len()), (10, 10));
        let mut a2 = a;
        a2.step = 5.0;
        a2.m[0] = 1.0;
        a2.reset_moments();
        assert_eq!(a2.step, 0.0);
        assert_eq!(a2.m[0], 0.0);
    }
}
