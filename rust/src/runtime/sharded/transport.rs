//! Shard data-plane messages and transports.
//!
//! One protocol, two carriers:
//!
//! * [`LoopbackTransport`] — in-process `mpsc` channels between the leader
//!   and shard worker threads. Payload vectors move (and the parameter
//!   snapshot travels as an `Arc`), so nothing is serialized — the
//!   testable path for the bitwise-parity suite.
//! * [`TcpShardTransport`] — every [`ShardMsg`] crosses the `comm::wire`
//!   framed codec as a shard-gradient [`Msg`], so multi-process
//!   deployments speak exactly the protocol the loopback path exercises.
//!
//! Protocol per fused step (leader's view, `seq` strictly increasing):
//!
//! 1. `Step` to every engaged shard (its row slice + current params) —
//!    shards run forward + per-row loss pieces in parallel;
//! 2. `Fwd` back from each shard;
//! 3. the gradient accumulator rings through the engaged shards in shard
//!    order — as one whole-model hop (`GradSeed` out, `GradOut` back), or,
//!    when overlap is on, as a pipeline of `GradBucket` windows so bucket
//!    k's hop hides under stage k+1's backward compute. Each bucketed
//!    backward ends with a `BucketFin` plan-agreement acknowledgement;
//! 4. optionally `GradFin` broadcast (replica-holding deployments apply
//!    the same optimizer update locally; stateless shards don't need it).
//!
//! Under the ZeRO plane (the default; `DYNAMIX_PLANE=replica` restores
//! the full-replica ring) step 3's windows travel as v4 `GradSlice`
//! frames — or their compressed `GradTopK`/`GradQ8` forms under
//! `DYNAMIX_WIRE` — and replica deployments exchange `ParamSlice`
//! all-gather legs instead of a full `GradFin` gradient.

use crate::comm::{Msg, ShardRows, Transport};
use std::sync::mpsc;
use std::sync::Arc;

/// One message of the shard data-plane protocol.
#[derive(Clone, Debug, PartialEq)]
pub enum ShardMsg {
    /// Begin one fused iteration. `denom` is the global fused-batch mask
    /// sum. `rows`/`params` are `None` for shards that own their data and
    /// hold a parameter replica (the TCP leader/worker deployment).
    Step {
        seq: u64,
        denom: f32,
        train: bool,
        rows: Option<ShardRows>,
        params: Option<Arc<Vec<f32>>>,
    },
    /// Forward half done: this shard's per-row loss terms + correctness.
    Fwd { seq: u64, loss_terms: Vec<f32>, correct: Vec<f32> },
    /// The traveling gradient accumulator (one chained-reduction hop).
    GradSeed { seq: u64, grad: Vec<f32> },
    /// The accumulator after folding this shard's rows in.
    GradOut { seq: u64, grad: Vec<f32> },
    /// One traveling **bucket** of the accumulator — a contiguous
    /// `[offset, offset + grad.len())` window of the flat gradient, used
    /// in both directions of a hop (seed in, folded window back).
    /// `bucket` is the window's index in the step's deterministic plan,
    /// carried for error attribution and in-order checking only — shards
    /// re-derive the stage run from `offset`/length against the layout.
    GradBucket { seq: u64, bucket: usize, offset: usize, grad: Vec<f32> },
    /// Shard → leader: the bucketed backward for step `seq` completed
    /// after exactly `buckets` buckets (the plan-agreement check).
    BucketFin { seq: u64, buckets: usize },
    /// One traveling **slice** of the ZeRO plane's accumulator — the
    /// dense window `[offset, offset + grad.len())`, hop `slice` of the
    /// step's partition-aligned plan (same schedule as `GradBucket`, a
    /// distinct frame so plane mismatches fail loudly).
    GradSlice { seq: u64, slice: usize, offset: usize, grad: Vec<f32> },
    /// A traveling slice under `DYNAMIX_WIRE=topk`: `len` is the dense
    /// window length; `idx`/`val` the kept elements in strictly
    /// increasing index order.
    GradTopK { seq: u64, slice: usize, offset: usize, len: usize, idx: Vec<u32>, val: Vec<f32> },
    /// A traveling slice under `DYNAMIX_WIRE=q8`: symmetric int8 with a
    /// per-window power-of-two f32 scale; dense length is `q.len()`.
    GradQ8 { seq: u64, slice: usize, offset: usize, scale: f32, q: Vec<i8> },
    /// An owner's updated parameter slice — the all-gather leg of the
    /// reduce-scatter plane (replica deployments only).
    ParamSlice { seq: u64, slice: usize, offset: usize, params: Vec<f32> },
    /// Fully-reduced gradient broadcast (replica deployments only). The
    /// moment triple mirrors wire v5's `ShardGradFin` — leader-computed
    /// stats ride the fin so an empty-gradient barrier still carries them.
    GradFin {
        seq: u64,
        loss: f32,
        acc: f32,
        sigma_norm: f32,
        sigma_norm2: f32,
        grad_l2: f32,
        grad: Vec<f32>,
    },
    /// The shard failed to process step `seq` but stays serviceable; the
    /// leader surfaces `msg` as the step's error.
    Err { seq: u64, msg: String },
    Shutdown,
}

impl ShardMsg {
    /// The step sequence a message belongs to (0 for `Shutdown`).
    pub fn seq(&self) -> u64 {
        match self {
            ShardMsg::Step { seq, .. }
            | ShardMsg::Fwd { seq, .. }
            | ShardMsg::GradSeed { seq, .. }
            | ShardMsg::GradOut { seq, .. }
            | ShardMsg::GradBucket { seq, .. }
            | ShardMsg::BucketFin { seq, .. }
            | ShardMsg::GradSlice { seq, .. }
            | ShardMsg::GradTopK { seq, .. }
            | ShardMsg::GradQ8 { seq, .. }
            | ShardMsg::ParamSlice { seq, .. }
            | ShardMsg::GradFin { seq, .. }
            | ShardMsg::Err { seq, .. } => *seq,
            ShardMsg::Shutdown => 0,
        }
    }

    /// Lower to the wire-level [`Msg`] (clones payloads; the loopback path
    /// never calls this).
    pub fn to_wire(&self) -> Msg {
        match self {
            ShardMsg::Step { seq, denom, train, rows, params } => Msg::ShardStep {
                seq: *seq,
                denom: *denom,
                train: *train,
                rows: rows.clone(),
                params: params.as_ref().map(|p| p.as_ref().clone()),
            },
            ShardMsg::Fwd { seq, loss_terms, correct } => Msg::ShardFwd {
                seq: *seq,
                loss_terms: loss_terms.clone(),
                correct: correct.clone(),
            },
            ShardMsg::GradSeed { seq, grad } => {
                Msg::ShardGradSeed { seq: *seq, grad: grad.clone() }
            }
            ShardMsg::GradOut { seq, grad } => Msg::ShardGradOut { seq: *seq, grad: grad.clone() },
            ShardMsg::GradBucket { seq, bucket, offset, grad } => Msg::ShardGradBucket {
                seq: *seq,
                bucket: *bucket as u32,
                offset: *offset as u64,
                grad: grad.clone(),
            },
            ShardMsg::BucketFin { seq, buckets } => {
                Msg::ShardBucketFin { seq: *seq, buckets: *buckets as u32 }
            }
            ShardMsg::GradSlice { seq, slice, offset, grad } => Msg::ShardGradSlice {
                seq: *seq,
                slice: *slice as u32,
                offset: *offset as u64,
                grad: grad.clone(),
            },
            ShardMsg::GradTopK { seq, slice, offset, len, idx, val } => Msg::ShardGradTopK {
                seq: *seq,
                slice: *slice as u32,
                offset: *offset as u64,
                len: *len as u64,
                idx: idx.clone(),
                val: val.clone(),
            },
            ShardMsg::GradQ8 { seq, slice, offset, scale, q } => Msg::ShardGradQ8 {
                seq: *seq,
                slice: *slice as u32,
                offset: *offset as u64,
                scale: *scale,
                q: q.clone(),
            },
            ShardMsg::ParamSlice { seq, slice, offset, params } => Msg::ShardParamSlice {
                seq: *seq,
                slice: *slice as u32,
                offset: *offset as u64,
                params: params.clone(),
            },
            ShardMsg::GradFin { seq, loss, acc, sigma_norm, sigma_norm2, grad_l2, grad } => {
                Msg::ShardGradFin {
                    seq: *seq,
                    loss: *loss,
                    acc: *acc,
                    sigma_norm: *sigma_norm,
                    sigma_norm2: *sigma_norm2,
                    grad_l2: *grad_l2,
                    grad: grad.clone(),
                }
            }
            ShardMsg::Err { seq, msg } => Msg::ShardErr { seq: *seq, msg: msg.clone() },
            ShardMsg::Shutdown => Msg::Shutdown,
        }
    }

    /// Lift a wire-level [`Msg`] back; errors on control-plane messages.
    pub fn from_wire(msg: Msg) -> anyhow::Result<ShardMsg> {
        Ok(match msg {
            Msg::ShardStep { seq, denom, train, rows, params } => ShardMsg::Step {
                seq,
                denom,
                train,
                rows,
                params: params.map(Arc::new),
            },
            Msg::ShardFwd { seq, loss_terms, correct } => {
                ShardMsg::Fwd { seq, loss_terms, correct }
            }
            Msg::ShardGradSeed { seq, grad } => ShardMsg::GradSeed { seq, grad },
            Msg::ShardGradOut { seq, grad } => ShardMsg::GradOut { seq, grad },
            Msg::ShardGradBucket { seq, bucket, offset, grad } => ShardMsg::GradBucket {
                seq,
                bucket: bucket as usize,
                offset: offset as usize,
                grad,
            },
            Msg::ShardBucketFin { seq, buckets } => {
                ShardMsg::BucketFin { seq, buckets: buckets as usize }
            }
            Msg::ShardGradSlice { seq, slice, offset, grad } => ShardMsg::GradSlice {
                seq,
                slice: slice as usize,
                offset: offset as usize,
                grad,
            },
            Msg::ShardGradTopK { seq, slice, offset, len, idx, val } => ShardMsg::GradTopK {
                seq,
                slice: slice as usize,
                offset: offset as usize,
                len: usize::try_from(len)
                    .map_err(|_| anyhow::anyhow!("topk dense length {len} overflows"))?,
                idx,
                val,
            },
            Msg::ShardGradQ8 { seq, slice, offset, scale, q } => ShardMsg::GradQ8 {
                seq,
                slice: slice as usize,
                offset: offset as usize,
                scale,
                q,
            },
            Msg::ShardParamSlice { seq, slice, offset, params } => ShardMsg::ParamSlice {
                seq,
                slice: slice as usize,
                offset: offset as usize,
                params,
            },
            Msg::ShardGradFin { seq, loss, acc, sigma_norm, sigma_norm2, grad_l2, grad } => {
                ShardMsg::GradFin { seq, loss, acc, sigma_norm, sigma_norm2, grad_l2, grad }
            }
            Msg::ShardErr { seq, msg } => ShardMsg::Err { seq, msg },
            Msg::Shutdown => ShardMsg::Shutdown,
            other => anyhow::bail!("not a shard data-plane message: {other:?}"),
        })
    }
}

/// Bidirectional [`ShardMsg`] channel between a leader and one shard.
pub trait ShardTransport: Send {
    fn send(&mut self, msg: ShardMsg) -> anyhow::Result<()>;
    fn recv(&mut self) -> anyhow::Result<ShardMsg>;

    /// A detached write half sharing this link, if the carrier supports
    /// one — lets the leader hand sends to the comm lane while it keeps
    /// blocking on `recv`. `None` (the default) means sends stay inline.
    fn sender(&self) -> Option<Box<dyn ShardSender>> {
        None
    }
}

/// Send-only half of a shard link (see [`ShardTransport::sender`]). Order
/// is only guaranteed among messages pushed through the SAME half, which
/// is why the comm lane is a single thread per process.
pub trait ShardSender: Send {
    fn send(&mut self, msg: ShardMsg) -> anyhow::Result<()>;
}

/// In-process transport: plain channels, zero serialization.
pub struct LoopbackTransport {
    tx: mpsc::Sender<ShardMsg>,
    rx: mpsc::Receiver<ShardMsg>,
}

/// A connected (leader end, shard end) pair of loopback transports.
pub fn loopback_pair() -> (LoopbackTransport, LoopbackTransport) {
    let (tx_a, rx_b) = mpsc::channel();
    let (tx_b, rx_a) = mpsc::channel();
    (
        LoopbackTransport { tx: tx_a, rx: rx_a },
        LoopbackTransport { tx: tx_b, rx: rx_b },
    )
}

impl ShardTransport for LoopbackTransport {
    fn send(&mut self, msg: ShardMsg) -> anyhow::Result<()> {
        self.tx.send(msg).map_err(|_| anyhow::anyhow!("shard peer closed"))
    }

    fn recv(&mut self) -> anyhow::Result<ShardMsg> {
        self.rx.recv().map_err(|_| anyhow::anyhow!("shard peer closed"))
    }

    fn sender(&self) -> Option<Box<dyn ShardSender>> {
        Some(Box::new(LoopbackSender { tx: self.tx.clone() }))
    }
}

/// Cloned write half of a loopback link.
struct LoopbackSender {
    tx: mpsc::Sender<ShardMsg>,
}

impl ShardSender for LoopbackSender {
    fn send(&mut self, msg: ShardMsg) -> anyhow::Result<()> {
        self.tx.send(msg).map_err(|_| anyhow::anyhow!("shard peer closed"))
    }
}

/// Wire transport: the same protocol over any framed `comm` transport
/// (TCP in production; the codec runs on every message either way).
pub struct TcpShardTransport<T: Transport> {
    inner: T,
}

impl<T: Transport> TcpShardTransport<T> {
    pub fn new(inner: T) -> Self {
        TcpShardTransport { inner }
    }
}

impl<T: Transport> ShardTransport for TcpShardTransport<T> {
    fn send(&mut self, msg: ShardMsg) -> anyhow::Result<()> {
        self.inner.send(&msg.to_wire())
    }

    fn recv(&mut self) -> anyhow::Result<ShardMsg> {
        ShardMsg::from_wire(self.inner.recv()?)
    }

    fn sender(&self) -> Option<Box<dyn ShardSender>> {
        self.inner
            .clone_writer()
            .map(|w| Box::new(WireSender { inner: w }) as Box<dyn ShardSender>)
    }
}

/// Write half of a wire link (a cloned OS handle under the framed codec).
struct WireSender {
    inner: Box<dyn Transport + Send>,
}

impl ShardSender for WireSender {
    fn send(&mut self, msg: ShardMsg) -> anyhow::Result<()> {
        self.inner.send(&msg.to_wire())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<ShardMsg> {
        vec![
            ShardMsg::Step {
                seq: 1,
                denom: 96.0,
                train: true,
                rows: Some(ShardRows {
                    model: "vgg11_mini".into(),
                    x: vec![0.25; 4],
                    y: vec![0, 9],
                    mask: vec![1.0, 1.0],
                }),
                params: Some(Arc::new(vec![0.5; 3])),
            },
            ShardMsg::Fwd { seq: 1, loss_terms: vec![1.0, 2.0], correct: vec![0.0, 1.0] },
            ShardMsg::GradSeed { seq: 1, grad: vec![0.0; 3] },
            ShardMsg::GradOut { seq: 1, grad: vec![0.1; 3] },
            ShardMsg::GradBucket { seq: 1, bucket: 2, offset: 650, grad: vec![0.5; 4] },
            ShardMsg::BucketFin { seq: 1, buckets: 3 },
            ShardMsg::GradSlice { seq: 1, slice: 0, offset: 0, grad: vec![0.5; 4] },
            ShardMsg::GradTopK {
                seq: 1,
                slice: 1,
                offset: 640,
                len: 8,
                idx: vec![0, 6],
                val: vec![1.5, -0.25],
            },
            ShardMsg::GradQ8 { seq: 1, slice: 2, offset: 64, scale: 0.03125, q: vec![3, -7, 127] },
            ShardMsg::ParamSlice { seq: 1, slice: 0, offset: 0, params: vec![0.5; 4] },
            ShardMsg::GradFin {
                seq: 1,
                loss: 1.5,
                acc: 0.5,
                sigma_norm: 0.75,
                sigma_norm2: 0.5625,
                grad_l2: 1.25,
                grad: vec![0.1; 3],
            },
            ShardMsg::Err { seq: 1, msg: "label 37 outside [0, 10)".into() },
            ShardMsg::Shutdown,
        ]
    }

    #[test]
    fn wire_mapping_roundtrips() {
        for m in sample() {
            let back = ShardMsg::from_wire(m.to_wire()).unwrap();
            assert_eq!(back, m);
        }
        // Control-plane messages don't lift.
        assert!(ShardMsg::from_wire(Msg::Barrier { cycle: 1 }).is_err());
    }

    #[test]
    fn loopback_pair_carries_messages_both_ways() {
        let (mut a, mut b) = loopback_pair();
        for m in sample() {
            a.send(m.clone()).unwrap();
            assert_eq!(b.recv().unwrap(), m);
            b.send(m.clone()).unwrap();
            assert_eq!(a.recv().unwrap(), m);
        }
        drop(b);
        assert!(a.recv().is_err(), "closed peer must error, not hang");
    }

    #[test]
    fn detached_sender_shares_the_link_in_order() {
        let (a, mut b) = loopback_pair();
        let mut s1 = a.sender().expect("loopback supports a write half");
        let mut s2 = a.sender().unwrap();
        // Single-half ordering: everything through s1 arrives in push order.
        for i in 0..4 {
            s1.send(ShardMsg::BucketFin { seq: i, buckets: 1 }).unwrap();
        }
        for i in 0..4 {
            assert_eq!(b.recv().unwrap().seq(), i);
        }
        s2.send(ShardMsg::Shutdown).unwrap();
        assert_eq!(b.recv().unwrap(), ShardMsg::Shutdown);
        // The detached half keeps the channel open past the transport.
        drop(a);
        s1.send(ShardMsg::BucketFin { seq: 9, buckets: 1 }).unwrap();
        assert_eq!(b.recv().unwrap().seq(), 9);
    }
}
