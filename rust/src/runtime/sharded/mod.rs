//! Sharded data-parallel [`ComputeBackend`]: the fused batch split across
//! N worker shards, with a **bit-exact** gradient all-reduce.
//!
//! ## Why the result is bit-identical to the native backend
//!
//! The native kernels have two properties the data plane exploits:
//!
//! 1. **Per-row forward independence** — every forward/loss/delta quantity
//!    of row `i` is a pure function of row `i` (the kernels' reduction
//!    association over the feature dims is fixed and never depends on the
//!    batch size), so a shard computing only its contiguous row slice
//!    reproduces the fused batch's per-row values bit for bit.
//! 2. **Sequential batch-dim reductions** — the weight/bias gradient
//!    kernels (`matmul_at`, `col_sums`) fold rows into the accumulator
//!    strictly in row order, per output element. Seeding shard `s`'s
//!    backward with shard `s-1`'s accumulated gradient therefore replays
//!    the fused fold exactly: the "all-reduce" is a chained deterministic
//!    reduction (a sequential ring pass), not an order-free partial sum.
//!
//! Scalar outputs (loss/acc) decompose the same way: shards return per-row
//! loss terms, and the leader folds them in row order with the same f64
//! accumulator sequence the fused loss uses (`fold_masked_ce_partial`).
//! The optimizer then applies leader-side to the identical gradient bits.
//! Net effect: `ShardedBackend::train_step` == `NativeBackend::train_step`
//! down to the last bit, for every shard count, every row split, every
//! kernel thread count and every `DYNAMIX_KERNEL` tier (the tiers all
//! preserve the sequential per-output-element row fold on `matmul_at` /
//! `col_sums`) — `tests/sharded_parity.rs` is the oracle.
//!
//! ## Elastic membership
//!
//! [`ComputeBackend::set_shard_active`] drops/revives shards; a dropped
//! shard's rows redistribute across survivors (via the same
//! `sim::elastic` helper the BSP trainer uses for worker churn), and since
//! any contiguous partition is exact, preemption mid-run never perturbs
//! the math — only who computes which rows.
//!
//! ## Exchange planes: ZeRO reduce-scatter vs full replica
//!
//! [`Plane::Zero`] (the default; `DYNAMIX_PLANE=replica` restores the old
//! ring) drives Phase B as a reduce-scatter: the accumulator's bucket
//! windows travel as v4 `GradSlice` frames (or compressed
//! `GradTopK`/`GradQ8` under `DYNAMIX_WIRE`), each shard owns the
//! contiguous bucket-aligned parameter slice `param_partition` assigns
//! it, and the optimizer applies slice-by-slice over that partition —
//! `apply_*_slice` is elementwise, so the sliced application is bitwise
//! the fused one. Dense zero rides the exact replica-ring schedule and
//! fold order, so it stays bit-identical to the fused native step;
//! compressed modes trade parity for wire bytes but remain exactly
//! reproducible run to run (`tests/zero_parity.rs` pins both contracts).
//! With overlap off the same slice pipeline runs at depth 1 (serialized
//! hops, identical fold order).

pub mod transport;
pub mod worker;

use crate::comm::ShardRows;
use crate::config::{Optimizer, PpoVariant};
use crate::runtime::backend::{
    ComputeBackend, OptState, PolicyOut, PpoHyper, PpoMinibatch, PpoStats, Schema, TrainOut,
};
use crate::comm::wire::{self, WireMode};
use crate::runtime::native::model::{
    apply_adam, apply_adam_slice, apply_sgd, apply_sgd_slice, fold_masked_ce_partial,
    normalized_grad_stats,
};
use crate::runtime::native::workspace::WireScratch;
use crate::runtime::native::{CommLane, NativeBackend};
use crate::sim::elastic;
use std::collections::VecDeque;
use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::JoinHandle;
use transport::{loopback_pair, ShardMsg, ShardSender, ShardTransport};

/// Default target bytes per gradient bucket (`DYNAMIX_BUCKET_KB`
/// overrides). 32 KiB ≈ one mid-sized dense layer's gradient: small
/// enough that the first hop starts long before the backward finishes,
/// large enough that framing overhead stays negligible.
const DEFAULT_BUCKET_BYTES: usize = 32 << 10;

/// Gradient-exchange plane of the sharded data plane (`DYNAMIX_PLANE`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Plane {
    /// PR 4/7 full-replica ring: every window is seeded, folded and
    /// applied against the whole parameter vector leader-side. Kept as
    /// the parity reference.
    Replica,
    /// ZeRO-style reduce-scatter (the default): windows travel as v4
    /// slice frames — compressible via [`WireMode`] — and the optimizer
    /// applies per owned parameter slice of the partition.
    #[default]
    Zero,
}

/// `DYNAMIX_PLANE` resolved to a [`Plane`] (unset/unrecognized -> zero).
fn env_plane() -> Plane {
    match crate::config::env::plane().as_deref() {
        Some("replica") => Plane::Replica,
        _ => Plane::Zero,
    }
}

/// Contiguous row ranges of a `bucket`-row fused batch, one per shard (in
/// shard order; inactive shards get empty ranges). Base assignment is
/// balanced (first `bucket % n` shards take one extra row); each inactive
/// shard's quota then folds onto the survivors through the exact
/// redistribution rule the elastic trainer applies to worker batches.
pub fn plan_rows(bucket: usize, active: &[bool]) -> Vec<Range<usize>> {
    let n = active.len();
    let mut counts: Vec<usize> = (0..n)
        .map(|s| bucket / n + usize::from(s < bucket % n))
        .collect();
    let caps = vec![bucket; n];
    for s in 0..n {
        if !active[s] && counts[s] > 0 {
            elastic::redistribute_freed(counts[s], &mut counts, active, &caps, bucket);
            counts[s] = 0;
        }
    }
    let mut out = Vec::with_capacity(n);
    let mut at = 0usize;
    for s in 0..n {
        let c = if active[s] { counts[s] } else { 0 };
        out.push(at..at + c);
        at += c;
    }
    debug_assert!(
        at == bucket || !active.iter().any(|&a| a),
        "row plan dropped rows: {at} != {bucket}"
    );
    out
}

/// Receive the next reply for step `seq` from one shard, skipping stale
/// replies left over from an earlier step that errored mid-protocol (an
/// aborted step can leave an unread `Fwd`/`Err` in the channel; dropping
/// them keeps the data plane usable after a failed call). A shard-side
/// [`ShardMsg::Err`] for the CURRENT step surfaces as this step's error;
/// a dead transport (killed socket, crashed peer) surfaces as a clean
/// shard-tagged error, never a hang — the caller can then drop the shard
/// via [`ComputeBackend::set_shard_active`] and retry the step on the
/// survivors (the optimizer state is untouched by a failed step).
fn recv_reply(
    link: &mut Box<dyn ShardTransport>,
    shard: usize,
    seq: u64,
) -> anyhow::Result<ShardMsg> {
    loop {
        let msg = link
            .recv()
            .map_err(|e| anyhow::anyhow!("shard {shard}: transport failed mid-step: {e:#}"))?;
        let mseq = msg.seq();
        match msg {
            ShardMsg::Fwd { .. } | ShardMsg::GradOut { .. } | ShardMsg::Err { .. }
                if mseq < seq =>
            {
                continue; // stale reply from an aborted step
            }
            // An aborted overlapped step leaves bucket/slice replies and
            // fin frames unread; drain those too. A CURRENT-seq frame
            // falls through to the protocol error below, whose debug print
            // names the offending seq and bucket id.
            ShardMsg::GradBucket { .. }
            | ShardMsg::BucketFin { .. }
            | ShardMsg::GradSlice { .. }
            | ShardMsg::GradTopK { .. }
            | ShardMsg::GradQ8 { .. }
            | ShardMsg::ParamSlice { .. }
                if mseq < seq =>
            {
                continue;
            }
            ShardMsg::Err { msg, .. } => anyhow::bail!("shard {shard}: {msg}"),
            other => return Ok(other),
        }
    }
}

/// Receive the reply for `bucket` of step `seq` from one ring position,
/// draining stale frames the same way [`recv_reply`] does. Every error
/// path names BOTH the offending `seq` and the bucket id — a mid-ring
/// failure is only debuggable if it says *which hop* died.
fn recv_bucket_reply(
    link: &mut Box<dyn ShardTransport>,
    shard: usize,
    seq: u64,
    bucket: usize,
) -> anyhow::Result<(usize, Vec<f32>)> {
    loop {
        let msg = link.recv().map_err(|e| {
            anyhow::anyhow!(
                "shard {shard}: transport failed mid-ring at seq {seq} bucket {bucket}: {e:#}"
            )
        })?;
        let mseq = msg.seq();
        match msg {
            ShardMsg::Fwd { .. }
            | ShardMsg::GradOut { .. }
            | ShardMsg::Err { .. }
            | ShardMsg::GradBucket { .. }
            | ShardMsg::BucketFin { .. }
            | ShardMsg::GradSlice { .. }
            | ShardMsg::GradTopK { .. }
            | ShardMsg::GradQ8 { .. }
            | ShardMsg::ParamSlice { .. }
                if mseq < seq =>
            {
                continue; // stale frame from an aborted step
            }
            ShardMsg::Err { msg, .. } => {
                anyhow::bail!("shard {shard}: bucket {bucket} of seq {seq}: {msg}")
            }
            ShardMsg::GradBucket { seq: rs, bucket: rb, offset, grad } => {
                anyhow::ensure!(
                    rs == seq && rb == bucket,
                    "shard {shard}: bucket reply (seq {rs}, bucket {rb}) != expected \
                     (seq {seq}, bucket {bucket})"
                );
                return Ok((offset, grad));
            }
            other => anyhow::bail!(
                "shard {shard}: expected bucket {bucket} of seq {seq}, got {other:?}"
            ),
        }
    }
}

/// Consume one shard's `BucketFin` — its acknowledgment that every stage
/// of step `seq`'s backward folded and retired shard-side.
fn recv_bucket_fin(
    link: &mut Box<dyn ShardTransport>,
    shard: usize,
    seq: u64,
    expected_buckets: usize,
) -> anyhow::Result<()> {
    loop {
        let msg = link.recv().map_err(|e| {
            anyhow::anyhow!(
                "shard {shard}: transport failed mid-ring at seq {seq} awaiting bucket fin: {e:#}"
            )
        })?;
        let mseq = msg.seq();
        match msg {
            ShardMsg::Fwd { .. }
            | ShardMsg::GradOut { .. }
            | ShardMsg::Err { .. }
            | ShardMsg::GradBucket { .. }
            | ShardMsg::BucketFin { .. }
            | ShardMsg::GradSlice { .. }
            | ShardMsg::GradTopK { .. }
            | ShardMsg::GradQ8 { .. }
            | ShardMsg::ParamSlice { .. }
                if mseq < seq =>
            {
                continue;
            }
            ShardMsg::Err { msg, .. } => {
                anyhow::bail!("shard {shard}: bucket fin of seq {seq}: {msg}")
            }
            ShardMsg::BucketFin { seq: rs, buckets } => {
                anyhow::ensure!(
                    rs == seq && buckets == expected_buckets,
                    "shard {shard}: bucket fin (seq {rs}, {buckets} buckets) != expected \
                     (seq {seq}, {expected_buckets} buckets)"
                );
                return Ok(());
            }
            other => anyhow::bail!(
                "shard {shard}: expected bucket fin of seq {seq}, got {other:?}"
            ),
        }
    }
}

/// Receive the reply for `slice` of step `seq` under the ZeRO plane: a
/// slice frame whose kind matches the configured wire mode (a shard that
/// answers dense to a q8 hop is a protocol error, not a silent fallback).
/// Stale frames drain exactly as in [`recv_bucket_reply`].
fn recv_slice_reply(
    link: &mut Box<dyn ShardTransport>,
    shard: usize,
    seq: u64,
    slice: usize,
    mode: WireMode,
) -> anyhow::Result<ShardMsg> {
    loop {
        let msg = link.recv().map_err(|e| {
            anyhow::anyhow!(
                "shard {shard}: transport failed mid-ring at seq {seq} slice {slice}: {e:#}"
            )
        })?;
        let mseq = msg.seq();
        match msg {
            ShardMsg::Fwd { .. }
            | ShardMsg::GradOut { .. }
            | ShardMsg::Err { .. }
            | ShardMsg::GradBucket { .. }
            | ShardMsg::BucketFin { .. }
            | ShardMsg::GradSlice { .. }
            | ShardMsg::GradTopK { .. }
            | ShardMsg::GradQ8 { .. }
            | ShardMsg::ParamSlice { .. }
                if mseq < seq =>
            {
                continue; // stale frame from an aborted step
            }
            ShardMsg::Err { msg, .. } => {
                anyhow::bail!("shard {shard}: slice {slice} of seq {seq}: {msg}")
            }
            frame => {
                let (rs, rslice, kind) = match &frame {
                    ShardMsg::GradSlice { seq, slice, .. } => (*seq, *slice, WireMode::Dense),
                    ShardMsg::GradTopK { seq, slice, .. } => (*seq, *slice, WireMode::TopK),
                    ShardMsg::GradQ8 { seq, slice, .. } => (*seq, *slice, WireMode::Q8),
                    other => anyhow::bail!(
                        "shard {shard}: expected slice {slice} of seq {seq}, got {other:?}"
                    ),
                };
                anyhow::ensure!(
                    kind == mode,
                    "shard {shard}: slice {slice} of seq {seq} replied in wire mode \
                     {} != configured {}",
                    kind.label(),
                    mode.label()
                );
                anyhow::ensure!(
                    rs == seq && rslice == slice,
                    "shard {shard}: slice reply (seq {rs}, slice {rslice}) != expected \
                     (seq {seq}, slice {slice})"
                );
                return Ok(frame);
            }
        }
    }
}

/// `(offset, dense length)` a slice frame claims to cover. Callers check
/// it against the bucket plan before staging or folding the frame.
fn slice_extent(msg: &ShardMsg) -> (usize, usize) {
    match msg {
        ShardMsg::GradSlice { offset, grad, .. } => (*offset, grad.len()),
        ShardMsg::GradTopK { offset, len, .. } => (*offset, *len),
        ShardMsg::GradQ8 { offset, q, .. } => (*offset, q.len()),
        other => unreachable!("slice_extent on non-slice frame {other:?}"),
    }
}

/// Decode a slice frame's payload into `out` (the final ring position's
/// reply, folded by every engaged shard). Targets a caller buffer so the
/// leader's steady-state decode allocates nothing once `out`'s capacity
/// covers the largest window.
fn decode_slice_into(msg: &ShardMsg, out: &mut Vec<f32>) -> anyhow::Result<()> {
    match msg {
        ShardMsg::GradSlice { grad, .. } => {
            out.clear();
            out.extend_from_slice(grad);
            Ok(())
        }
        ShardMsg::GradTopK { len, idx, val, .. } => wire::topk_decode_into(*len, idx, val, out),
        ShardMsg::GradQ8 { scale, q, .. } => wire::q8_decode_into(*scale, q, out),
        other => anyhow::bail!("decode_slice: not a slice frame: {other:?}"),
    }
}

/// The sharded data plane. One leader (the caller's thread) plus N shard
/// workers behind [`ShardTransport`]s — in-process loopback threads by
/// default, or any framed-socket peers via
/// [`ShardedBackend::over_transports`].
pub struct ShardedBackend {
    inner: Arc<NativeBackend>,
    links: Mutex<Vec<Box<dyn ShardTransport>>>,
    /// Detached write halves (where the transport can supply one), cloned
    /// into comm-lane jobs so ring sends run off the leader thread.
    /// Behind a lock so [`ShardedBackend::reattach_transport`] can swap a
    /// rejoining shard's half together with its link.
    senders: Mutex<Vec<Option<Arc<Mutex<Box<dyn ShardSender>>>>>>,
    /// The single send thread behind overlapped ring hops; lazily spawned
    /// on the first overlapped train step.
    lane: OnceLock<CommLane>,
    active: Mutex<Vec<bool>>,
    handles: Mutex<Vec<JoinHandle<()>>>,
    seq: AtomicU64,
    n: usize,
    /// Pipelined bucket ring on/off (`DYNAMIX_OVERLAP`, read once at
    /// construction; default on). Off reproduces the bulk PR 5 ring
    /// under the replica plane, and serializes the slice pipeline to
    /// depth 1 under the zero plane.
    overlap: bool,
    /// Target bytes per gradient bucket (`DYNAMIX_BUCKET_KB`).
    bucket_bytes: usize,
    /// Exchange plane (`DYNAMIX_PLANE`, read once at construction).
    plane: Plane,
    /// Slice payload codec for the zero plane (`DYNAMIX_WIRE`).
    wire: WireMode,
    /// Leader-side decode scratch for the final ring hop — reused across
    /// steps so the steady-state decode path allocates nothing.
    scratch: Mutex<WireScratch>,
}

impl ShardedBackend {
    /// Loopback data plane: `n` shard worker threads over in-process
    /// channels, kernels at the `DYNAMIX_THREADS` pool.
    pub fn loopback(n: usize) -> Self {
        Self::build(Arc::new(NativeBackend::new()), n)
    }

    /// Loopback with a pinned kernel thread count (tests pin both axes —
    /// shard count and thread count — without touching the process env).
    pub fn loopback_with_threads(n: usize, threads: usize) -> Self {
        Self::build(Arc::new(NativeBackend::with_threads(threads)), n)
    }

    /// Loopback with every execution axis pinned — shard count, kernel
    /// thread count and kernel tier — for the per-tier parity sweep.
    pub fn loopback_with_kernel(
        n: usize,
        threads: usize,
        tier: crate::runtime::native::KernelTier,
    ) -> Self {
        Self::build(Arc::new(NativeBackend::with_kernel(threads, tier)), n)
    }

    fn build(inner: Arc<NativeBackend>, n: usize) -> Self {
        let n = n.clamp(1, 64);
        let mut links: Vec<Box<dyn ShardTransport>> = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for s in 0..n {
            let (leader_end, shard_end) = loopback_pair();
            let backend = inner.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("dynamix-shard-{s}"))
                    .spawn(move || {
                        // Errors surface leader-side as closed channels.
                        let _ = worker::serve(shard_end, backend);
                    })
                    .expect("spawn shard worker thread"),
            );
            links.push(Box::new(leader_end));
        }
        let senders = links
            .iter()
            .map(|l| l.sender().map(|s| Arc::new(Mutex::new(s))))
            .collect();
        ShardedBackend {
            inner,
            n,
            links: Mutex::new(links),
            senders: Mutex::new(senders),
            lane: OnceLock::new(),
            active: Mutex::new(vec![true; n]),
            handles: Mutex::new(handles),
            seq: AtomicU64::new(0),
            overlap: crate::config::env::overlap().unwrap_or(true),
            bucket_bytes: crate::config::env::bucket_kb()
                .map(|kb| kb * 1024)
                .unwrap_or(DEFAULT_BUCKET_BYTES),
            plane: env_plane(),
            wire: crate::config::env::wire_mode().unwrap_or(WireMode::Dense),
            scratch: Mutex::default(),
        }
    }

    /// Pin the overlap axes — ring schedule and bucket target — without
    /// touching the process environment (the parity sweeps pin every axis
    /// explicitly; env vars would race across concurrent tests).
    /// `bucket_bytes == 0` means one bucket per completion stage, the
    /// finest legal plan.
    pub fn with_overlap(mut self, overlap: bool, bucket_bytes: usize) -> Self {
        self.overlap = overlap;
        self.bucket_bytes = bucket_bytes;
        self
    }

    /// Pin the exchange plane explicitly (the parity sweeps compare
    /// `Plane::Zero` against `Plane::Replica` without touching the
    /// process environment).
    pub fn with_plane(mut self, plane: Plane) -> Self {
        self.plane = plane;
        self
    }

    /// Pin the zero-plane slice codec explicitly. Ignored under the
    /// replica plane, whose frames are always dense buckets.
    pub fn with_wire(mut self, wire: WireMode) -> Self {
        self.wire = wire;
        self
    }

    /// The configured exchange plane.
    pub fn plane(&self) -> Plane {
        self.plane
    }

    /// The configured zero-plane slice codec.
    pub fn wire(&self) -> WireMode {
        self.wire
    }

    /// Data plane over caller-supplied transports (e.g. TCP shard servers
    /// accepted elsewhere). The caller owns the server lifetimes.
    pub fn over_transports(
        inner: Arc<NativeBackend>,
        links: Vec<Box<dyn ShardTransport>>,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(!links.is_empty(), "sharded backend needs at least one transport");
        let n = links.len();
        let senders = links
            .iter()
            .map(|l| l.sender().map(|s| Arc::new(Mutex::new(s))))
            .collect();
        Ok(ShardedBackend {
            inner,
            n,
            links: Mutex::new(links),
            senders: Mutex::new(senders),
            lane: OnceLock::new(),
            active: Mutex::new(vec![true; n]),
            handles: Mutex::new(Vec::new()),
            seq: AtomicU64::new(0),
            overlap: crate::config::env::overlap().unwrap_or(true),
            bucket_bytes: crate::config::env::bucket_kb()
                .map(|kb| kb * 1024)
                .unwrap_or(DEFAULT_BUCKET_BYTES),
            plane: env_plane(),
            wire: crate::config::env::wire_mode().unwrap_or(WireMode::Dense),
            scratch: Mutex::default(),
        })
    }

    /// Re-admit a dropped shard by attaching a fresh transport — the
    /// data-plane half of the reconnect/rejoin handshake. Shards hold no
    /// cross-step state (`Step` ships rows + params every iteration), so
    /// swapping the link is a complete rejoin: after this returns, flip
    /// the shard back in with `set_shard_active(shard, true)` (or let the
    /// trainer's `rejoin_worker` scenario handling do it — its resumed
    /// batch comes from `sim::elastic::rejoin_batch`). The shard must be
    /// OUT of the membership while its link is swapped; queued comm-lane
    /// sends still holding the dead write half fail harmlessly against
    /// the closed socket.
    pub fn reattach_transport(
        &self,
        shard: usize,
        link: Box<dyn ShardTransport>,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(shard < self.n, "shard {shard} out of range (n = {})", self.n);
        anyhow::ensure!(
            !self.shard_membership()[shard],
            "shard {shard} is still in the membership — deactivate it before reattaching"
        );
        let sender = link.sender().map(|s| Arc::new(Mutex::new(s)));
        // Swap under both locks (links before senders, the ring-hop
        // order) so no hop can pair the new link with the old half.
        let mut links = self.links.lock().unwrap();
        let mut senders = self.senders.lock().unwrap();
        links[shard] = link;
        senders[shard] = sender;
        Ok(())
    }

    /// The wrapped single-process backend (schema + policy ops source).
    pub fn inner(&self) -> &Arc<NativeBackend> {
        &self.inner
    }

    /// Scatter rows + gather per-row loss pieces; optionally ring-reduce
    /// the gradient. Returns `(loss_sum, acc_sum, denom, grad)` — `denom`
    /// is the fused mask sum the f64 sums divide by, `grad` is `Some` only
    /// for train steps. Appends per-row correctness to `correct_out` in
    /// row order when provided.
    #[allow(clippy::too_many_arguments)]
    fn exchange(
        &self,
        model: &str,
        params: &[f32],
        param_count: usize,
        feature_dim: usize,
        x: &[f32],
        y: &[i32],
        mask: &[f32],
        train: bool,
        mut correct_out: Option<&mut Vec<f32>>,
    ) -> anyhow::Result<(f64, f64, f32, Option<Vec<f32>>, Vec<bool>)> {
        let m = mask.len();
        anyhow::ensure!(x.len() == m * feature_dim, "x wrong size");
        anyhow::ensure!(y.len() == m, "y wrong size");
        // PARITY: same sequential fold as the fused loss's denominator in
        // `masked_ce_loss_ws` — identical bits across shard counts.
        let denom = mask.iter().sum::<f32>().max(1.0);
        let active = self.active.lock().unwrap().clone();
        anyhow::ensure!(active.iter().any(|&a| a), "no active shards");
        let plan = plan_rows(m, &active);
        let params = Arc::new(params.to_vec());
        let seq = self.seq.fetch_add(1, Ordering::Relaxed) + 1;
        let mut links = self.links.lock().unwrap();

        // Phase A: scatter; engaged shards run forward concurrently.
        let mut engaged: Vec<usize> = Vec::with_capacity(self.n);
        for (s, r) in plan.iter().enumerate() {
            if r.is_empty() {
                continue;
            }
            links[s]
                .send(ShardMsg::Step {
                    seq,
                    denom,
                    train,
                    rows: Some(ShardRows {
                        model: model.to_string(),
                        x: x[r.start * feature_dim..r.end * feature_dim].to_vec(),
                        y: y[r.clone()].to_vec(),
                        mask: mask[r.clone()].to_vec(),
                    }),
                    params: Some(params.clone()),
                })
                .map_err(|e| anyhow::anyhow!("shard {s}: transport failed mid-step: {e:#}"))?;
            engaged.push(s);
        }

        // Gather: shard order == row order, so the f64 loss/acc folds see
        // exactly the fused accumulator sequence.
        let (mut loss_sum, mut acc_sum) = (0.0f64, 0.0f64);
        for &s in &engaged {
            match recv_reply(&mut links[s], s, seq)? {
                ShardMsg::Fwd { seq: rs, loss_terms, correct } => {
                    anyhow::ensure!(rs == seq, "shard {s}: Fwd seq {rs} != {seq}");
                    fold_masked_ce_partial(&loss_terms, &correct, &mut loss_sum, &mut acc_sum);
                    if let Some(out) = correct_out.as_mut() {
                        out.extend_from_slice(&correct);
                    }
                }
                other => anyhow::bail!("shard {s}: expected Fwd, got {other:?}"),
            }
        }

        // Phase B: the chained deterministic reduction — the accumulator
        // visits engaged shards in row order; each folds its rows in.
        // Overlapped, the accumulator travels as completion-ordered
        // buckets so hop k rides under the compute of stage k+1; bulk, it
        // travels whole. Same seeds, same per-element fold order — the
        // two schedules are bit-identical (`tests/overlap_parity.rs`).
        // The zero plane always drives the pipelined schedule (depth 1
        // when overlap is off) so its windows travel as slice frames; a
        // single engaged shard exchanges nothing in a real deployment and
        // takes the bulk path regardless of plane.
        let grad = if train {
            let mut grad = vec![0.0f32; param_count];
            let ring = engaged.len() > 1 && (self.overlap || self.plane == Plane::Zero);
            if ring {
                let r = self.ring_pipelined(&mut links, &engaged, seq, model, &mut grad);
                // Settle the comm lane before surfacing anything: a failed
                // step must not leak queued sends (or their errors) into
                // the next one.
                let sends = self.lane.get().map_or(Ok(()), |l| l.drain());
                r?;
                sends?;
            } else {
                for &s in &engaged {
                    links[s]
                        .send(ShardMsg::GradSeed { seq, grad })
                        .map_err(|e| {
                            anyhow::anyhow!("shard {s}: transport failed mid-ring: {e:#}")
                        })?;
                    grad = match recv_reply(&mut links[s], s, seq)? {
                        ShardMsg::GradOut { seq: rs, grad } => {
                            anyhow::ensure!(rs == seq, "shard {s}: GradOut seq {rs} != {seq}");
                            grad
                        }
                        other => anyhow::bail!("shard {s}: expected GradOut, got {other:?}"),
                    };
                }
            }
            Some(grad)
        } else {
            None
        };
        Ok((loss_sum, acc_sum, denom, grad, active))
    }

    /// The pipelined ring (Phase B): split the traveling accumulator into
    /// the deterministic bucket plan (see
    /// [`crate::runtime::native::model::ModelDef::bucket_plan`]) and drive
    /// every window through the engaged shards in row order, keeping at
    /// most `depth` windows in flight per link. While window `k` hops,
    /// each shard is folding (or prepping) the stages behind window `k+1`
    /// — the communication hides under backward compute instead of
    /// serializing after it. Under the replica plane windows travel as
    /// `GradBucket` frames; under the zero plane they travel as the
    /// configured slice frames, with compressed replies forwarded
    /// verbatim hop to hop.
    ///
    /// PARITY: the schedule moves, the arithmetic does not. Window `k`'s
    /// seed at position `j` is exactly the window position `j-1` produced
    /// (zeros at position 0), and shards fold stages in completion order
    /// under cursors that forbid reordering — so every per-element row
    /// fold happens in the same sequence as the bulk ring and the fused
    /// native step. That makes replica-overlapped, zero-dense (any
    /// depth) and fused-native bit-identical; topk/q8 fold DECODED
    /// windows and are deterministic but not parity.
    fn ring_pipelined(
        &self,
        links: &mut [Box<dyn ShardTransport>],
        engaged: &[usize],
        seq: u64,
        model: &str,
        grad: &mut [f32],
    ) -> anyhow::Result<()> {
        let plan = self.inner.bucket_plan(model, self.bucket_bytes)?;
        let nb = plan.len();
        let p = engaged.len();
        let zero = self.plane == Plane::Zero;
        // Per-link in-flight cap. Pipelining needs at most one bucket on
        // the wire plus one queued behind it; an unbounded window could
        // fill a TCP send buffer while this thread is blocked reading a
        // different link (send/recv deadlock against the shard). With
        // overlap off the cap drops to 1: hops serialize, and since the
        // fold order is position-by-position identical either way, the
        // two depths are bit-identical.
        let depth: usize = if self.overlap { 2 } else { 1 };
        let mut sent = vec![0usize; p];
        let mut recvd = vec![0usize; p];
        // Frames received from ring position j-1, awaiting the hop to j.
        // Under the zero plane a shard's reply is forwarded VERBATIM as
        // the next hop's input — compressed payloads decode only at the
        // fold site and at the final copy-out, never in transit.
        let mut staged: Vec<VecDeque<ShardMsg>> = (0..p).map(|_| VecDeque::new()).collect();
        while recvd[p - 1] < nb {
            // Greedy sends: every bucket whose upstream window landed and
            // whose link has window room goes out now. Position 0 seeds
            // from the zeroed accumulator directly.
            for j in 0..p {
                while sent[j] < nb
                    && sent[j] - recvd[j] < depth
                    && (j == 0 || !staged[j].is_empty())
                {
                    let b = sent[j];
                    let msg = if j == 0 {
                        let win = grad[plan[b].offset..plan[b].offset + plan[b].len].to_vec();
                        if zero {
                            self.encode_slice(seq, b, plan[b].offset, win)
                        } else {
                            ShardMsg::GradBucket { seq, bucket: b, offset: plan[b].offset, grad: win }
                        }
                    } else {
                        staged[j].pop_front().expect("checked non-empty")
                    };
                    self.send_ring_hop(&mut links[engaged[j]], engaged[j], seq, b, msg)?;
                    sent[j] += 1;
                }
            }
            // Deterministic blocking recv: among positions with a reply
            // outstanding, take the smallest (bucket, position) — the
            // schedule never depends on arrival timing.
            let j = (0..p)
                .filter(|&j| recvd[j] < sent[j])
                .min_by_key(|&j| (recvd[j], j))
                .expect("overlapped ring stalled with buckets outstanding");
            let b = recvd[j];
            let s = engaged[j];
            if zero {
                let reply = recv_slice_reply(&mut links[s], s, seq, b, self.wire)?;
                let (off, len) = slice_extent(&reply);
                anyhow::ensure!(
                    off == plan[b].offset && len == plan[b].len,
                    "shard {s}: slice {b} of seq {seq} window [{off}, {}) != planned [{}, {})",
                    off + len,
                    plan[b].offset,
                    plan[b].offset + plan[b].len
                );
                if j == p - 1 {
                    // Fully reduced: every engaged shard folded its rows
                    // in. Decode into the pooled scratch — no per-step
                    // window allocation.
                    let mut scratch = self.scratch.lock().unwrap();
                    decode_slice_into(&reply, &mut scratch.dense)?;
                    grad[off..off + scratch.dense.len()].copy_from_slice(&scratch.dense);
                } else {
                    staged[j + 1].push_back(reply);
                }
            } else {
                let (off, win) = recv_bucket_reply(&mut links[s], s, seq, b)?;
                anyhow::ensure!(
                    off == plan[b].offset && win.len() == plan[b].len,
                    "shard {s}: bucket {b} of seq {seq} window [{off}, {}) != planned [{}, {})",
                    off + win.len(),
                    plan[b].offset,
                    plan[b].offset + plan[b].len
                );
                if j == p - 1 {
                    grad[off..off + win.len()].copy_from_slice(&win);
                } else {
                    staged[j + 1].push_back(ShardMsg::GradBucket {
                        seq,
                        bucket: b,
                        offset: off,
                        grad: win,
                    });
                }
            }
            recvd[j] += 1;
        }
        // Every link acknowledges full retirement before the step ends —
        // a shard that silently skipped stages would fail here.
        for &s in engaged {
            recv_bucket_fin(&mut links[s], s, seq, nb)?;
        }
        Ok(())
    }

    /// Wrap one accumulator window in the configured zero-plane slice
    /// frame. Compression happens here (leader seed hop) and shard-side
    /// on each reply — both directions of every hop carry the compressed
    /// form.
    fn encode_slice(&self, seq: u64, slice: usize, offset: usize, win: Vec<f32>) -> ShardMsg {
        match self.wire {
            WireMode::Dense => ShardMsg::GradSlice { seq, slice, offset, grad: win },
            WireMode::TopK => {
                let len = win.len();
                let (idx, val) = wire::topk_encode(&win);
                ShardMsg::GradTopK { seq, slice, offset, len, idx, val }
            }
            WireMode::Q8 => {
                let (scale, q) = wire::q8_encode(&win);
                ShardMsg::GradQ8 { seq, slice, offset, scale, q }
            }
        }
    }

    /// One leader->shard bucket send. Runs on the comm lane (off the
    /// leader thread, via the transport's detached write half) when the
    /// transport supports it, inline otherwise; either way the error
    /// names the seq and bucket of the hop that failed.
    fn send_ring_hop(
        &self,
        link: &mut Box<dyn ShardTransport>,
        shard: usize,
        seq: u64,
        bucket: usize,
        msg: ShardMsg,
    ) -> anyhow::Result<()> {
        let half = self.senders.lock().unwrap()[shard].clone();
        if let Some(half) = half {
            self.lane.get_or_init(CommLane::new).submit(move || {
                half.lock()
                    .map_err(|_| anyhow::anyhow!("sender half poisoned"))?
                    .send(msg)
                    .map_err(|e| {
                        anyhow::anyhow!(
                            "shard {shard}: transport failed mid-ring at seq {seq} \
                             bucket {bucket}: {e:#}"
                        )
                    })
            });
            Ok(())
        } else {
            link.send(msg).map_err(|e| {
                anyhow::anyhow!(
                    "shard {shard}: transport failed mid-ring at seq {seq} bucket {bucket}: {e:#}"
                )
            })
        }
    }
}

impl Drop for ShardedBackend {
    fn drop(&mut self) {
        // Retire the comm lane first: it flushes queued sends on drop, so
        // no bucket frame can race the Shutdown below on a shared link.
        drop(self.lane.take());
        if let Ok(mut links) = self.links.lock() {
            for l in links.iter_mut() {
                let _ = l.send(ShardMsg::Shutdown);
            }
        }
        if let Ok(mut handles) = self.handles.lock() {
            for h in handles.drain(..) {
                let _ = h.join();
            }
        }
    }
}

impl ComputeBackend for ShardedBackend {
    fn name(&self) -> &'static str {
        "sharded"
    }

    fn schema(&self) -> &Schema {
        self.inner.schema()
    }

    fn init_params(&self, model: &str, seed: u64) -> anyhow::Result<Vec<f32>> {
        self.inner.init_params(model, seed)
    }

    fn init_policy(&self, seed: u64) -> anyhow::Result<Vec<f32>> {
        self.inner.init_policy(seed)
    }

    // The PPO arbitrator is centralized in the paper's architecture;
    // policy math stays leader-local on the inner backend.
    fn policy_forward(&self, theta: &[f32], states: &[f32]) -> anyhow::Result<PolicyOut> {
        self.inner.policy_forward(theta, states)
    }

    fn policy_update(
        &self,
        variant: PpoVariant,
        opt: &mut OptState,
        mb: &PpoMinibatch,
        hp: PpoHyper,
    ) -> anyhow::Result<PpoStats> {
        self.inner.policy_update(variant, opt, mb, hp)
    }

    fn train_step(
        &self,
        model: &str,
        optimizer: Optimizer,
        bucket: usize,
        state: &mut OptState,
        x: &[f32],
        y: &[i32],
        mask: &[f32],
        lr: f32,
    ) -> anyhow::Result<TrainOut> {
        let mut out = TrainOut::default();
        self.train_step_into(model, optimizer, bucket, state, x, y, mask, lr, &mut out)?;
        Ok(out)
    }

    fn train_step_into(
        &self,
        model: &str,
        optimizer: Optimizer,
        bucket: usize,
        state: &mut OptState,
        x: &[f32],
        y: &[i32],
        mask: &[f32],
        lr: f32,
        out: &mut TrainOut,
    ) -> anyhow::Result<()> {
        let info = self.inner.schema().model(model)?.clone();
        anyhow::ensure!(
            state.params.len() == info.param_count,
            "params len {} != {}",
            state.params.len(),
            info.param_count
        );
        anyhow::ensure!(
            self.inner.schema().buckets.contains(&bucket),
            "bucket {bucket} not on the ladder"
        );
        anyhow::ensure!(mask.len() == bucket, "mask wrong size");
        out.correct.clear();
        let (loss_sum, acc_sum, denom, grad, active) = self.exchange(
            model,
            &state.params,
            info.param_count,
            info.feature_dim,
            x,
            y,
            mask,
            true,
            Some(&mut out.correct),
        )?;
        let grad = grad.expect("train exchange returns a gradient");
        let (sigma_norm, sigma_norm2, grad_l2) = normalized_grad_stats(&grad);
        match self.plane {
            Plane::Replica => match optimizer {
                Optimizer::Sgd => apply_sgd(self.inner.pool(), state, &grad, lr),
                Optimizer::Adam => apply_adam(self.inner.pool(), state, &grad, lr),
            },
            // PARITY: the partition is a disjoint contiguous cover of the
            // parameter vector and both optimizers are elementwise, so
            // applying slice-by-slice (step bumped once, Adam's bias
            // correction computed once) produces the fused application's
            // bits exactly — `slice_optimizer_application_matches_fused_
            // bitwise` in native::model pins this.
            Plane::Zero => {
                let parts = self.inner.param_partition(model, &active, self.bucket_bytes)?;
                state.step += 1.0;
                match optimizer {
                    Optimizer::Sgd => {
                        for r in parts {
                            if !r.is_empty() {
                                apply_sgd_slice(
                                    self.inner.pool(),
                                    &mut state.params[r.clone()],
                                    &mut state.m[r.clone()],
                                    &grad[r],
                                    lr,
                                );
                            }
                        }
                    }
                    Optimizer::Adam => {
                        let t = state.step as f64;
                        for r in parts {
                            if !r.is_empty() {
                                apply_adam_slice(
                                    self.inner.pool(),
                                    &mut state.params[r.clone()],
                                    &mut state.m[r.clone()],
                                    &mut state.v[r.clone()],
                                    &grad[r],
                                    lr,
                                    t,
                                );
                            }
                        }
                    }
                }
            }
        }
        out.loss = (loss_sum / denom as f64) as f32;
        out.acc = (acc_sum / denom as f64) as f32;
        out.sigma_norm = sigma_norm;
        out.sigma_norm2 = sigma_norm2;
        out.grad_l2 = grad_l2;
        Ok(())
    }

    fn eval_step(
        &self,
        model: &str,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        mask: &[f32],
    ) -> anyhow::Result<(f32, f32)> {
        let info = self.inner.schema().model(model)?.clone();
        anyhow::ensure!(params.len() == info.param_count, "params len mismatch");
        let (loss_sum, acc_sum, denom, _, _) = self.exchange(
            model,
            params,
            info.param_count,
            info.feature_dim,
            x,
            y,
            mask,
            false,
            None,
        )?;
        Ok((
            (loss_sum / denom as f64) as f32,
            (acc_sum / denom as f64) as f32,
        ))
    }

    fn shard_count(&self) -> usize {
        self.n
    }

    fn shard_membership(&self) -> Vec<bool> {
        self.active.lock().unwrap().clone()
    }

    fn set_shard_active(&self, shard: usize, active: bool) -> bool {
        let mut m = self.active.lock().unwrap();
        if shard >= m.len() || m[shard] == active {
            return false;
        }
        if !active && m.iter().filter(|&&a| a).count() <= 1 {
            return false; // never empty the data plane
        }
        m[shard] = active;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_covers_bucket_with_balanced_contiguous_ranges() {
        for (bucket, n) in [(32usize, 1usize), (32, 2), (103, 4), (5, 7), (64, 7)] {
            let plan = plan_rows(bucket, &vec![true; n]);
            assert_eq!(plan.len(), n);
            let mut at = 0;
            for r in &plan {
                assert_eq!(r.start, at, "ranges must be contiguous in order");
                at = r.end;
            }
            assert_eq!(at, bucket);
            let sizes: Vec<usize> = plan.iter().map(|r| r.len()).collect();
            let (mn, mx) = (
                *sizes.iter().min().unwrap(),
                *sizes.iter().max().unwrap(),
            );
            assert!(mx - mn <= 1, "unbalanced plan {sizes:?}");
        }
    }

    #[test]
    fn plan_redistributes_inactive_shard_rows_to_survivors() {
        let mut active = vec![true; 4];
        active[1] = false;
        let plan = plan_rows(103, &active);
        assert!(plan[1].is_empty());
        assert_eq!(plan.iter().map(|r| r.len()).sum::<usize>(), 103);
        // Survivors absorbed the dropped quota.
        assert!(plan[0].len() + plan[2].len() + plan[3].len() == 103);
        let mut at = 0;
        for r in &plan {
            assert_eq!(r.start, at);
            at = r.end;
        }
    }

    #[test]
    fn plane_and_wire_builders_pin_the_exchange_axes() {
        // Builder round-trip only — the env-derived defaults are not
        // asserted here because CI sweeps DYNAMIX_PLANE/DYNAMIX_WIRE
        // across the whole test binary.
        let b = ShardedBackend::loopback_with_threads(2, 1)
            .with_plane(Plane::Replica)
            .with_wire(WireMode::Q8);
        assert_eq!(b.plane(), Plane::Replica);
        assert_eq!(b.wire(), WireMode::Q8);
        let b = b.with_plane(Plane::Zero).with_wire(WireMode::TopK);
        assert_eq!(b.plane(), Plane::Zero);
        assert_eq!(b.wire(), WireMode::TopK);
    }

    #[test]
    fn membership_guards_hold() {
        let b = ShardedBackend::loopback_with_threads(3, 1);
        assert_eq!(b.shard_count(), 3);
        assert!(!b.set_shard_active(7, false), "out of range");
        assert!(!b.set_shard_active(0, true), "no-op activation");
        assert!(b.set_shard_active(0, false));
        assert!(b.set_shard_active(1, false));
        assert!(!b.set_shard_active(2, false), "last shard must survive");
        assert_eq!(b.shard_membership(), vec![false, false, true]);
        assert!(b.set_shard_active(0, true));
    }
}
