//! Shard-server state machine: serves the data-plane protocol for
//! **stateless** compute shards — the loopback worker threads and
//! socket-serving shard processes. Such shards receive their row slice and
//! the current parameters with every `Step` and hold nothing between
//! steps except the in-flight forward state awaiting its `GradSeed`.
//!
//! Data-owning workers with parameter replicas (the TCP demo in
//! `comm::leader`) drive the same message flow with their own loop,
//! because they sample rows locally and apply `GradFin` updates.

use super::transport::{ShardMsg, ShardTransport};
use crate::runtime::native::workspace::WireScratch;
use crate::runtime::native::{NativeBackend, ShardCtx};
use std::sync::Arc;

/// One shard's protocol handler. Transport-agnostic: feed it messages,
/// send back whatever it returns.
pub struct ShardServer {
    backend: Arc<NativeBackend>,
    /// In-flight step awaiting its GradSeed / gradient buckets:
    /// (seq, params, forward state).
    held: Option<(u64, Arc<Vec<f32>>, ShardCtx)>,
    /// Buckets folded for the in-flight step (the overlapped ring's
    /// in-order check: bucket `k` must be the `k`-th frame to arrive).
    buckets_done: usize,
    /// Per-hop decode/fold buffers — reply payloads additionally reuse
    /// the incoming frame's own vectors, so a steady-state hop performs
    /// zero heap allocations (regression-tested).
    scratch: WireScratch,
}

impl ShardServer {
    pub fn new(backend: Arc<NativeBackend>) -> Self {
        ShardServer { backend, held: None, buckets_done: 0, scratch: WireScratch::default() }
    }

    /// Bytes reserved in the per-hop decode/fold scratch — flat across
    /// steady-state hops (the zero-allocation regression test pins it).
    pub fn scratch_capacity_bytes(&self) -> usize {
        self.scratch.capacity_bytes()
    }

    /// Handle one gradient bucket of the overlapped ring: seed the
    /// `[offset, offset + grad.len())` window, fold this bucket's stages,
    /// and return the folded window as the reply (in the incoming frame's
    /// recycled buffer). The caller must send the reply FIRST and only
    /// then call [`Self::bucket_retire`] — the follow-up work (prep-ahead
    /// / retirement) runs while the bucket hops to the next shard, which
    /// is exactly the overlap this pipeline exists for.
    pub fn handle_bucket(
        &mut self,
        seq: u64,
        bucket: usize,
        offset: usize,
        mut grad: Vec<f32>,
    ) -> anyhow::Result<ShardMsg> {
        self.fold_window(seq, bucket, offset, &grad)?;
        grad.clear();
        grad.extend_from_slice(&self.scratch.fold);
        Ok(ShardMsg::GradBucket { seq, bucket, offset, grad })
    }

    /// Shared in-order fold core of the bucketed replica ring and the
    /// ZeRO slice plane: seed the `[offset, offset + grad.len())` window,
    /// fold this window's stages, bump the in-order cursor. The folded
    /// window lands in `self.scratch.fold` (valid until the next call).
    fn fold_window(
        &mut self,
        seq: u64,
        bucket: usize,
        offset: usize,
        grad: &[f32],
    ) -> anyhow::Result<()> {
        let (held_seq, params, ctx) = self.held.as_mut().ok_or_else(|| {
            anyhow::anyhow!("bucket {bucket} (seq {seq}) without an in-flight step")
        })?;
        anyhow::ensure!(
            *held_seq == seq,
            "bucket {bucket} seq {seq} != in-flight step {held_seq}"
        );
        anyhow::ensure!(
            bucket == self.buckets_done,
            "bucket {bucket} of seq {seq} arrived out of order (expected bucket {})",
            self.buckets_done
        );
        self.backend
            .shard_backward_bucket(params, ctx, offset, grad, &mut self.scratch.fold)?;
        self.buckets_done += 1;
        Ok(())
    }

    /// Handle one ZeRO-plane slice frame: decode its payload to the dense
    /// window, fold with the same in-order machinery as
    /// [`Self::handle_bucket`] (the slice id is the bucket index), and
    /// re-encode the folded window in the SAME wire mode for the reply.
    /// Same reply-before-retire contract as buckets. Compressed modes are
    /// lossy on purpose: the fold input is the decoded window and the
    /// reply re-compresses, which is deterministic but not bit-parity
    /// with the dense plane. Decode targets the pooled scratch and the
    /// reply payloads recycle the incoming frame's vectors — no per-hop
    /// allocations once the buffers are warm.
    pub fn handle_slice(&mut self, msg: ShardMsg) -> anyhow::Result<ShardMsg> {
        use crate::comm::wire;
        match msg {
            ShardMsg::GradSlice { seq, slice, offset, mut grad } => {
                self.fold_window(seq, slice, offset, &grad)?;
                grad.clear();
                grad.extend_from_slice(&self.scratch.fold);
                Ok(ShardMsg::GradSlice { seq, slice, offset, grad })
            }
            ShardMsg::GradTopK { seq, slice, offset, len, mut idx, mut val } => {
                let mut dense = std::mem::take(&mut self.scratch.dense);
                let folded = wire::topk_decode_into(len, &idx, &val, &mut dense)
                    .and_then(|()| self.fold_window(seq, slice, offset, &dense));
                self.scratch.dense = dense;
                folded?;
                let mut order = std::mem::take(&mut self.scratch.order);
                wire::topk_encode_into(&self.scratch.fold, &mut order, &mut idx, &mut val);
                self.scratch.order = order;
                Ok(ShardMsg::GradTopK { seq, slice, offset, len, idx, val })
            }
            ShardMsg::GradQ8 { seq, slice, offset, scale, mut q } => {
                let mut dense = std::mem::take(&mut self.scratch.dense);
                let folded = wire::q8_decode_into(scale, &q, &mut dense)
                    .and_then(|()| self.fold_window(seq, slice, offset, &dense));
                self.scratch.dense = dense;
                folded?;
                let scale = wire::q8_encode_into(&self.scratch.fold, &mut q);
                Ok(ShardMsg::GradQ8 { seq, slice, offset, scale, q })
            }
            other => anyhow::bail!("handle_slice: not a slice frame: {other:?}"),
        }
    }

    /// Post-reply step of the bucket protocol: if every stage has folded,
    /// retire the step and hand back the `BucketFin` frame to send;
    /// otherwise pre-run the next stage's dx-propagation (the compute
    /// that overlaps the in-flight bucket's ring hop) and return `None`.
    pub fn bucket_retire(&mut self, seq: u64) -> anyhow::Result<Option<ShardMsg>> {
        let Some((held_seq, params, ctx)) = self.held.as_mut() else {
            return Ok(None);
        };
        if *held_seq != seq {
            return Ok(None);
        }
        if self.backend.shard_backward_done(ctx)? {
            let (_, _, ctx) = self.held.take().expect("held checked above");
            self.backend.shard_finish(ctx)?;
            let buckets = self.buckets_done;
            self.buckets_done = 0;
            Ok(Some(ShardMsg::BucketFin { seq, buckets }))
        } else {
            self.backend.shard_backward_prep_ahead(params, ctx)?;
            Ok(None)
        }
    }

    /// Handle one message; `Ok(Some(reply))` goes back to the leader.
    /// `Shutdown` is the caller's concern (see [`serve`]).
    pub fn handle(&mut self, msg: ShardMsg) -> anyhow::Result<Option<ShardMsg>> {
        match msg {
            ShardMsg::Step { seq, denom, train, rows, params } => {
                let rows =
                    rows.ok_or_else(|| anyhow::anyhow!("stateless shard got Step without rows"))?;
                let params = params
                    .ok_or_else(|| anyhow::anyhow!("stateless shard got Step without params"))?;
                // A stale held step means the leader abandoned a sequence
                // (error recovery); recycle its workspace and move on. A
                // partially-bucketed backward is discarded the same way.
                if let Some((_, _, ctx)) = self.held.take() {
                    self.backend.shard_discard(ctx);
                }
                self.buckets_done = 0;
                let (ctx, fwd) = self.backend.shard_forward(
                    &rows.model,
                    &params,
                    rows.x,
                    &rows.y,
                    &rows.mask,
                    denom,
                )?;
                if train {
                    self.held = Some((seq, params, ctx));
                } else {
                    self.backend.shard_discard(ctx);
                }
                Ok(Some(ShardMsg::Fwd {
                    seq,
                    loss_terms: fwd.loss_terms,
                    correct: fwd.correct,
                }))
            }
            ShardMsg::GradSeed { seq, mut grad } => {
                anyhow::ensure!(
                    self.buckets_done == 0,
                    "GradSeed for seq {seq} after {} gradient buckets — a step reduces \
                     through buckets or bulk, never both",
                    self.buckets_done
                );
                let (held_seq, params, ctx) = self
                    .held
                    .take()
                    .ok_or_else(|| anyhow::anyhow!("GradSeed without an in-flight step"))?;
                anyhow::ensure!(
                    held_seq == seq,
                    "GradSeed seq {seq} != in-flight step {held_seq}"
                );
                self.backend.shard_backward_acc(&params, ctx, &mut grad)?;
                Ok(Some(ShardMsg::GradOut { seq, grad }))
            }
            // Stateless shards hold no replica; the reduced gradient is
            // applied leader-side. Tolerated for protocol symmetry.
            ShardMsg::GradFin { .. } => Ok(None),
            ShardMsg::Shutdown => Ok(None),
            other => anyhow::bail!("shard server: unexpected {other:?}"),
        }
    }
}

/// Serve one transport until `Shutdown` (or transport failure). Handler
/// errors (bad inputs, protocol abuse) are reported back as
/// [`ShardMsg::Err`] and the shard keeps serving — a poisoned step must
/// not take the worker down with it.
pub fn serve(mut transport: impl ShardTransport, backend: Arc<NativeBackend>) -> anyhow::Result<()> {
    let mut server = ShardServer::new(backend);
    loop {
        let msg = transport.recv()?;
        if msg == ShardMsg::Shutdown {
            return Ok(());
        }
        let seq = msg.seq();
        // Buckets are special-cased so the folded window goes on the wire
        // BEFORE the follow-up compute: the next shard starts folding (and
        // this shard preps its next stage) while later stages here are
        // still pending — that concurrency is the comm/compute overlap.
        if let ShardMsg::GradBucket { seq, bucket, offset, grad } = msg {
            match server.handle_bucket(seq, bucket, offset, grad) {
                Ok(reply) => {
                    transport.send(reply)?;
                    match server.bucket_retire(seq) {
                        Ok(Some(fin)) => transport.send(fin)?,
                        Ok(None) => {}
                        Err(e) => {
                            transport.send(ShardMsg::Err { seq, msg: format!("{e:#}") })?
                        }
                    }
                }
                Err(e) => transport.send(ShardMsg::Err { seq, msg: format!("{e:#}") })?,
            }
            continue;
        }
        // ZeRO-plane slice frames follow the exact bucket discipline
        // (reply first, retire/prep-ahead after) — the slice id rides the
        // same in-order cursor.
        if matches!(
            msg,
            ShardMsg::GradSlice { .. } | ShardMsg::GradTopK { .. } | ShardMsg::GradQ8 { .. }
        ) {
            match server.handle_slice(msg) {
                Ok(reply) => {
                    transport.send(reply)?;
                    match server.bucket_retire(seq) {
                        Ok(Some(fin)) => transport.send(fin)?,
                        Ok(None) => {}
                        Err(e) => {
                            transport.send(ShardMsg::Err { seq, msg: format!("{e:#}") })?
                        }
                    }
                }
                Err(e) => transport.send(ShardMsg::Err { seq, msg: format!("{e:#}") })?,
            }
            continue;
        }
        match server.handle(msg) {
            Ok(Some(reply)) => transport.send(reply)?,
            Ok(None) => {}
            Err(e) => transport.send(ShardMsg::Err { seq, msg: format!("{e:#}") })?,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn server_rejects_protocol_abuse() {
        let mut s = ShardServer::new(Arc::new(NativeBackend::with_threads(1)));
        // GradSeed with nothing in flight.
        let err = s
            .handle(ShardMsg::GradSeed { seq: 1, grad: vec![0.0; 4] })
            .unwrap_err()
            .to_string();
        assert!(err.contains("in-flight"), "{err}");
        // Step without rows/params.
        assert!(s
            .handle(ShardMsg::Step { seq: 2, denom: 1.0, train: true, rows: None, params: None })
            .is_err());
        // Fwd is a shard->leader message; a shard must never receive it.
        assert!(s
            .handle(ShardMsg::Fwd { seq: 3, loss_terms: vec![], correct: vec![] })
            .is_err());
    }

    #[test]
    fn bucket_frames_are_checked_before_any_fold() {
        use crate::comm::ShardRows;
        let b = Arc::new(NativeBackend::with_threads(1));
        let fd = b.schema().feature_dim;
        let params = Arc::new(b.init_params("vgg11_mini", 0).unwrap());
        let mut s = ShardServer::new(b);
        // Bucket with nothing in flight.
        let err = s.handle_bucket(1, 0, 0, vec![0.0; 4]).unwrap_err().to_string();
        assert!(err.contains("without an in-flight step"), "{err}");
        s.handle(ShardMsg::Step {
            seq: 5,
            denom: 2.0,
            train: true,
            rows: Some(ShardRows {
                model: "vgg11_mini".into(),
                x: vec![0.1; 2 * fd],
                y: vec![0, 1],
                mask: vec![1.0, 1.0],
            }),
            params: Some(params),
        })
        .unwrap();
        // Wrong seq: the error carries BOTH the seq and the bucket id.
        let err = s.handle_bucket(9, 0, 0, vec![0.0; 4]).unwrap_err().to_string();
        assert!(err.contains("seq 9") && err.contains("bucket 0"), "{err}");
        // Out-of-order bucket index.
        let err = s.handle_bucket(5, 3, 0, vec![0.0; 4]).unwrap_err().to_string();
        assert!(err.contains("out of order") && err.contains("bucket 3"), "{err}");
        // A window that is not a stage run at the fold cursor.
        let err = s.handle_bucket(5, 0, 1, vec![0.0; 4]).unwrap_err().to_string();
        assert!(err.contains("fold cursor"), "{err}");
        // Rejected buckets folded nothing, so the bulk path still works.
        let reply =
            s.handle(ShardMsg::GradSeed { seq: 5, grad: vec![0.0; 25_546] }).unwrap().unwrap();
        assert!(matches!(reply, ShardMsg::GradOut { seq: 5, .. }));
    }

    #[test]
    fn slice_frames_fold_and_reply_in_their_own_wire_mode() {
        use crate::comm::ShardRows;
        let b = Arc::new(NativeBackend::with_threads(1));
        let fd = b.schema().feature_dim;
        let params = Arc::new(b.init_params("vgg11_mini", 0).unwrap());
        let pc = params.len();
        let mut s = ShardServer::new(b);
        let step = |seq| ShardMsg::Step {
            seq,
            denom: 2.0,
            train: true,
            rows: Some(ShardRows {
                model: "vgg11_mini".into(),
                x: vec![0.1; 2 * fd],
                y: vec![0, 1],
                mask: vec![1.0, 1.0],
            }),
            params: Some(Arc::clone(&params)),
        };
        // Dense slice covering the whole model folds and replies GradSlice.
        s.handle(step(5)).unwrap().unwrap();
        let reply = s
            .handle_slice(ShardMsg::GradSlice { seq: 5, slice: 0, offset: 0, grad: vec![0.0; pc] })
            .unwrap();
        let ShardMsg::GradSlice { seq: 5, slice: 0, offset: 0, grad } = reply else {
            panic!("dense slice must reply GradSlice, got {reply:?}");
        };
        assert_eq!(grad.len(), pc);
        assert!(grad.iter().any(|&g| g != 0.0), "fold produced an all-zero gradient");
        assert!(matches!(
            s.bucket_retire(5).unwrap(),
            Some(ShardMsg::BucketFin { seq: 5, buckets: 1 })
        ));
        // Q8 slice decodes, folds, and replies Q8 (not dense).
        s.handle(step(6)).unwrap().unwrap();
        let reply = s
            .handle_slice(ShardMsg::GradQ8 {
                seq: 6,
                slice: 0,
                offset: 0,
                scale: 0.0,
                q: vec![0; pc],
            })
            .unwrap();
        assert!(matches!(reply, ShardMsg::GradQ8 { seq: 6, slice: 0, offset: 0, .. }), "{reply:?}");
        s.bucket_retire(6).unwrap();
        // Non-slice frames are rejected by handle_slice, and a slice with
        // nothing in flight is an error like any bucket.
        assert!(s.handle_slice(ShardMsg::Shutdown).is_err());
        let err = s
            .handle_slice(ShardMsg::GradSlice { seq: 9, slice: 0, offset: 0, grad: vec![0.0; 4] })
            .unwrap_err()
            .to_string();
        assert!(err.contains("without an in-flight step"), "{err}");
    }

    #[test]
    fn seq_mismatch_is_an_error() {
        use crate::comm::ShardRows;
        let b = Arc::new(NativeBackend::with_threads(1));
        let fd = b.schema().feature_dim;
        let params = Arc::new(b.init_params("vgg11_mini", 0).unwrap());
        let mut s = ShardServer::new(b);
        let step = ShardMsg::Step {
            seq: 5,
            denom: 2.0,
            train: true,
            rows: Some(ShardRows {
                model: "vgg11_mini".into(),
                x: vec![0.1; 2 * fd],
                y: vec![0, 1],
                mask: vec![1.0, 1.0],
            }),
            params: Some(params),
        };
        let reply = s.handle(step).unwrap().unwrap();
        assert!(matches!(reply, ShardMsg::Fwd { seq: 5, .. }));
        let pc = 25_546;
        let err = s
            .handle(ShardMsg::GradSeed { seq: 6, grad: vec![0.0; pc] })
            .unwrap_err()
            .to_string();
        assert!(err.contains("seq"), "{err}");
    }
}
