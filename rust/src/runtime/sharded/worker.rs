//! Shard-server state machine: serves the data-plane protocol for
//! **stateless** compute shards — the loopback worker threads and
//! socket-serving shard processes. Such shards receive their row slice and
//! the current parameters with every `Step` and hold nothing between
//! steps except the in-flight forward state awaiting its `GradSeed`.
//!
//! Data-owning workers with parameter replicas (the TCP demo in
//! `comm::leader`) drive the same message flow with their own loop,
//! because they sample rows locally and apply `GradFin` updates.

use super::transport::{ShardMsg, ShardTransport};
use crate::runtime::native::{NativeBackend, ShardCtx};
use std::sync::Arc;

/// One shard's protocol handler. Transport-agnostic: feed it messages,
/// send back whatever it returns.
pub struct ShardServer {
    backend: Arc<NativeBackend>,
    /// In-flight step awaiting its GradSeed: (seq, params, forward state).
    held: Option<(u64, Arc<Vec<f32>>, ShardCtx)>,
}

impl ShardServer {
    pub fn new(backend: Arc<NativeBackend>) -> Self {
        ShardServer { backend, held: None }
    }

    /// Handle one message; `Ok(Some(reply))` goes back to the leader.
    /// `Shutdown` is the caller's concern (see [`serve`]).
    pub fn handle(&mut self, msg: ShardMsg) -> anyhow::Result<Option<ShardMsg>> {
        match msg {
            ShardMsg::Step { seq, denom, train, rows, params } => {
                let rows =
                    rows.ok_or_else(|| anyhow::anyhow!("stateless shard got Step without rows"))?;
                let params = params
                    .ok_or_else(|| anyhow::anyhow!("stateless shard got Step without params"))?;
                // A stale held step means the leader abandoned a sequence
                // (error recovery); recycle its workspace and move on.
                if let Some((_, _, ctx)) = self.held.take() {
                    self.backend.shard_discard(ctx);
                }
                let (ctx, fwd) = self.backend.shard_forward(
                    &rows.model,
                    &params,
                    rows.x,
                    &rows.y,
                    &rows.mask,
                    denom,
                )?;
                if train {
                    self.held = Some((seq, params, ctx));
                } else {
                    self.backend.shard_discard(ctx);
                }
                Ok(Some(ShardMsg::Fwd {
                    seq,
                    loss_terms: fwd.loss_terms,
                    correct: fwd.correct,
                }))
            }
            ShardMsg::GradSeed { seq, mut grad } => {
                let (held_seq, params, ctx) = self
                    .held
                    .take()
                    .ok_or_else(|| anyhow::anyhow!("GradSeed without an in-flight step"))?;
                anyhow::ensure!(
                    held_seq == seq,
                    "GradSeed seq {seq} != in-flight step {held_seq}"
                );
                self.backend.shard_backward_acc(&params, ctx, &mut grad)?;
                Ok(Some(ShardMsg::GradOut { seq, grad }))
            }
            // Stateless shards hold no replica; the reduced gradient is
            // applied leader-side. Tolerated for protocol symmetry.
            ShardMsg::GradFin { .. } => Ok(None),
            ShardMsg::Shutdown => Ok(None),
            other => anyhow::bail!("shard server: unexpected {other:?}"),
        }
    }
}

/// Serve one transport until `Shutdown` (or transport failure). Handler
/// errors (bad inputs, protocol abuse) are reported back as
/// [`ShardMsg::Err`] and the shard keeps serving — a poisoned step must
/// not take the worker down with it.
pub fn serve(mut transport: impl ShardTransport, backend: Arc<NativeBackend>) -> anyhow::Result<()> {
    let mut server = ShardServer::new(backend);
    loop {
        let msg = transport.recv()?;
        if msg == ShardMsg::Shutdown {
            return Ok(());
        }
        let seq = msg.seq();
        match server.handle(msg) {
            Ok(Some(reply)) => transport.send(reply)?,
            Ok(None) => {}
            Err(e) => transport.send(ShardMsg::Err { seq, msg: format!("{e:#}") })?,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn server_rejects_protocol_abuse() {
        let mut s = ShardServer::new(Arc::new(NativeBackend::with_threads(1)));
        // GradSeed with nothing in flight.
        let err = s
            .handle(ShardMsg::GradSeed { seq: 1, grad: vec![0.0; 4] })
            .unwrap_err()
            .to_string();
        assert!(err.contains("in-flight"), "{err}");
        // Step without rows/params.
        assert!(s
            .handle(ShardMsg::Step { seq: 2, denom: 1.0, train: true, rows: None, params: None })
            .is_err());
        // Fwd is a shard->leader message; a shard must never receive it.
        assert!(s
            .handle(ShardMsg::Fwd { seq: 3, loss_terms: vec![], correct: vec![] })
            .is_err());
    }

    #[test]
    fn seq_mismatch_is_an_error() {
        use crate::comm::ShardRows;
        let b = Arc::new(NativeBackend::with_threads(1));
        let fd = b.schema().feature_dim;
        let params = Arc::new(b.init_params("vgg11_mini", 0).unwrap());
        let mut s = ShardServer::new(b);
        let step = ShardMsg::Step {
            seq: 5,
            denom: 2.0,
            train: true,
            rows: Some(ShardRows {
                model: "vgg11_mini".into(),
                x: vec![0.1; 2 * fd],
                y: vec![0, 1],
                mask: vec![1.0, 1.0],
            }),
            params: Some(params),
        };
        let reply = s.handle(step).unwrap().unwrap();
        assert!(matches!(reply, ShardMsg::Fwd { seq: 5, .. }));
        let pc = 25_546;
        let err = s
            .handle(ShardMsg::GradSeed { seq: 6, grad: vec![0.0; pc] })
            .unwrap_err()
            .to_string();
        assert!(err.contains("seq"), "{err}");
    }
}
