//! PJRT runtime: load AOT artifacts (HLO text) and execute them.
//!
//! This is the only module that touches the `xla` crate. The hot path is
//! `ArtifactStore::get(name)` (lazy compile + cache) followed by
//! `Executable::run(&[Literal])`. On the CPU PJRT plugin "device" memory
//! is host memory, so literal-based execution costs a memcpy per argument
//! — negligible against the train-step compute (measured in
//! EXPERIMENTS.md §Perf; the buffer-resident alternative is documented in
//! DESIGN.md §Perf and was rejected because tuple-rooted executables
//! return a single tuple buffer through this PJRT API).

mod manifest;
mod store;

pub use manifest::{ArtifactMeta, IoSpec, Manifest, ModelInfo};
pub use store::{ArtifactStore, Outputs};

use xla::Literal;

/// Build an f32 literal of the given shape from a slice.
pub fn lit_f32(data: &[f32], dims: &[i64]) -> anyhow::Result<Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape {dims:?} != len {}", data.len());
    let l = Literal::vec1(data);
    if dims.len() == 1 {
        Ok(l)
    } else {
        Ok(l.reshape(dims)?)
    }
}

/// Build an i32 literal of the given shape from a slice.
pub fn lit_i32(data: &[i32], dims: &[i64]) -> anyhow::Result<Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape {dims:?} != len {}", data.len());
    let l = Literal::vec1(data);
    if dims.len() == 1 {
        Ok(l)
    } else {
        Ok(l.reshape(dims)?)
    }
}

/// Scalar-as-[1] f32 literal (the AOT signature convention for lr/step...).
pub fn lit_scalar1(v: f32) -> Literal {
    Literal::vec1(&[v])
}
