//! Compute runtime: the [`ComputeBackend`] seam and its implementations.
//!
//! Everything above this module (RL agent, BSP trainer, baselines, harness)
//! talks to a `Backend` (`Arc<dyn ComputeBackend>`) and never to a concrete
//! runtime. Three backends exist:
//!
//! * **native** (default) — pure-Rust MLP forward/backward, PPO losses and
//!   optimizers mirroring `python/compile/` (`kernels/ref.py` semantics).
//!   Self-contained: no artifacts, no Python, no external deps.
//! * **sharded** — data-parallel data plane over the native kernels: the
//!   fused batch splits across `DYNAMIX_SHARDS` worker shards (loopback
//!   threads in-process, or framed sockets) with a chained deterministic
//!   gradient reduction that is bit-identical to the native backend.
//! * **xla** (`backend-xla` feature) — the original PJRT path: AOT HLO
//!   artifacts produced by `make artifacts`, lazily compiled and cached by
//!   `ArtifactStore`. Requires the `xla` crate (see rust/Cargo.toml).
//!
//! Selection: `DYNAMIX_BACKEND=native|sharded|xla|auto` (default `auto`:
//! xla when compiled in *and* artifacts are present, otherwise native).

pub mod backend;
pub mod manifest;
pub mod native;
pub mod sharded;
#[cfg(feature = "backend-xla")]
mod store;
#[cfg(feature = "backend-xla")]
mod xla_backend;

pub use backend::{
    apply_kernel_request, apply_wire_request, backend_for, default_backend, native_backend,
    sharded_backend, Backend,
    ComputeBackend, OptState, PolicyOut, PpoHyper, PpoMinibatch, PpoStats, Schema, TrainOut,
};
pub use manifest::{ArtifactMeta, IoSpec, Manifest, ModelInfo};
pub use native::{KernelTier, NativeBackend};
pub use sharded::{Plane, ShardedBackend};
#[cfg(feature = "backend-xla")]
pub use store::{ArtifactStore, Outputs};
#[cfg(feature = "backend-xla")]
pub use xla_backend::XlaBackend;

#[cfg(feature = "backend-xla")]
mod literals {
    use xla::Literal;

    /// Build an f32 literal of the given shape from a slice.
    pub fn lit_f32(data: &[f32], dims: &[i64]) -> anyhow::Result<Literal> {
        let n: i64 = dims.iter().product();
        anyhow::ensure!(n as usize == data.len(), "shape {dims:?} != len {}", data.len());
        let l = Literal::vec1(data);
        if dims.len() == 1 {
            Ok(l)
        } else {
            Ok(l.reshape(dims)?)
        }
    }

    /// Build an i32 literal of the given shape from a slice.
    pub fn lit_i32(data: &[i32], dims: &[i64]) -> anyhow::Result<Literal> {
        let n: i64 = dims.iter().product();
        anyhow::ensure!(n as usize == data.len(), "shape {dims:?} != len {}", data.len());
        let l = Literal::vec1(data);
        if dims.len() == 1 {
            Ok(l)
        } else {
            Ok(l.reshape(dims)?)
        }
    }

    /// Scalar-as-[1] f32 literal (the AOT signature convention for lr/step...).
    pub fn lit_scalar1(v: f32) -> Literal {
        Literal::vec1(&[v])
    }
}

#[cfg(feature = "backend-xla")]
pub use literals::{lit_f32, lit_i32, lit_scalar1};
