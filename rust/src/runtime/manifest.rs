//! `artifacts/manifest.json` parsing.
//!
//! The manifest is the single source of truth for artifact I/O schemas:
//! the Rust side never hardcodes parameter counts or buffer shapes — it
//! sizes everything from here, so a Python-side model change only requires
//! `make artifacts`.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One input or output tensor of an artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct IoSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl IoSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn dims_i64(&self) -> Vec<i64> {
        self.shape.iter().map(|&d| d as i64).collect()
    }
}

/// Metadata for one HLO artifact.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub kind: String,
    pub file: String,
    pub model: Option<String>,
    pub optimizer: Option<String>,
    pub bucket: Option<usize>,
    pub param_count: Option<usize>,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

/// Static description of one model in the zoo.
#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub family: String,
    pub depth: usize,
    pub width: usize,
    pub num_classes: usize,
    pub feature_dim: usize,
    pub param_count: usize,
    pub dataset: String,
}

/// Parsed manifest.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub buckets: Vec<usize>,
    pub eval_batch: usize,
    pub state_dim: usize,
    pub n_actions: usize,
    pub max_workers: usize,
    pub ppo_minibatch: usize,
    pub feature_dim: usize,
    pub policy_param_count: usize,
    pub init_seeds: usize,
    pub models: BTreeMap<String, ModelInfo>,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
}

fn io_specs(v: &Json) -> anyhow::Result<Vec<IoSpec>> {
    v.as_arr()
        .ok_or_else(|| anyhow::anyhow!("io spec not an array"))?
        .iter()
        .map(|s| {
            Ok(IoSpec {
                shape: s
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow::anyhow!("missing shape"))?
                    .iter()
                    .map(|d| d.as_usize().ok_or_else(|| anyhow::anyhow!("bad dim")))
                    .collect::<anyhow::Result<_>>()?,
                dtype: s
                    .get("dtype")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow::anyhow!("missing dtype"))?
                    .to_string(),
            })
        })
        .collect()
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("reading {path:?}: {e}; run `make artifacts` first"))?;
        let v = Json::parse(&text)?;
        let need_u = |k: &str| {
            v.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow::anyhow!("manifest missing {k}"))
        };

        let mut models = BTreeMap::new();
        for (name, m) in v
            .get("models")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow::anyhow!("manifest missing models"))?
        {
            let gu = |k: &str| {
                m.get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow::anyhow!("model {name} missing {k}"))
            };
            models.insert(
                name.clone(),
                ModelInfo {
                    family: m.get("family").and_then(Json::as_str).unwrap_or("").into(),
                    depth: gu("depth")?,
                    width: gu("width")?,
                    num_classes: gu("num_classes")?,
                    feature_dim: gu("feature_dim")?,
                    param_count: gu("param_count")?,
                    dataset: m.get("dataset").and_then(Json::as_str).unwrap_or("").into(),
                },
            );
        }

        let mut artifacts = BTreeMap::new();
        for (name, a) in v
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow::anyhow!("manifest missing artifacts"))?
        {
            artifacts.insert(
                name.clone(),
                ArtifactMeta {
                    name: name.clone(),
                    kind: a
                        .get("kind")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow::anyhow!("artifact {name} missing kind"))?
                        .to_string(),
                    file: a
                        .get("file")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow::anyhow!("artifact {name} missing file"))?
                        .to_string(),
                    model: a.get("model").and_then(Json::as_str).map(str::to_string),
                    optimizer: a.get("optimizer").and_then(Json::as_str).map(str::to_string),
                    bucket: a.get("bucket").and_then(Json::as_usize),
                    param_count: a.get("param_count").and_then(Json::as_usize),
                    inputs: io_specs(a.get("inputs").unwrap_or(&Json::Null))?,
                    outputs: io_specs(a.get("outputs").unwrap_or(&Json::Null))?,
                },
            );
        }

        let buckets: Vec<usize> = v
            .get("buckets")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("manifest missing buckets"))?
            .iter()
            .map(|b| b.as_usize().ok_or_else(|| anyhow::anyhow!("bad bucket")))
            .collect::<anyhow::Result<_>>()?;
        anyhow::ensure!(buckets.windows(2).all(|w| w[0] < w[1]), "buckets not sorted");

        Ok(Manifest {
            dir: dir.to_path_buf(),
            buckets,
            eval_batch: need_u("eval_batch")?,
            state_dim: need_u("state_dim")?,
            n_actions: need_u("n_actions")?,
            max_workers: need_u("max_workers")?,
            ppo_minibatch: need_u("ppo_minibatch")?,
            feature_dim: need_u("feature_dim")?,
            policy_param_count: need_u("policy_param_count")?,
            init_seeds: v.get("init_seeds").and_then(Json::as_usize).unwrap_or(0),
            models,
            artifacts,
        })
    }

    /// Smallest bucket >= n, or an error if n exceeds the ladder.
    pub fn bucket_for(&self, n: usize) -> anyhow::Result<usize> {
        self.buckets
            .iter()
            .copied()
            .find(|&b| b >= n)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "batch {n} exceeds largest bucket {}",
                    self.buckets.last().copied().unwrap_or(0)
                )
            })
    }

    /// Artifact name for a train step.
    pub fn train_artifact(&self, model: &str, optimizer: &str, bucket: usize) -> String {
        format!("train_{model}_{optimizer}_b{bucket}")
    }

    /// Artifact name for an eval step.
    pub fn eval_artifact(&self, model: &str) -> String {
        format!("eval_{model}")
    }

    pub fn model(&self, name: &str) -> anyhow::Result<&ModelInfo> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown model {name:?}"))
    }

    pub fn artifact(&self, name: &str) -> anyhow::Result<&ArtifactMeta> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown artifact {name:?}"))
    }

    /// Load a seeded init-parameter snapshot (raw little-endian f32).
    pub fn load_init_params(&self, model: &str, seed: u64) -> anyhow::Result<Vec<f32>> {
        let seed = if self.init_seeds > 0 {
            seed % self.init_seeds as u64
        } else {
            0
        };
        let path = self.dir.join(format!("init_{model}_seed{seed}.f32"));
        read_f32_file(&path)
    }

    /// Load a seeded policy init snapshot.
    pub fn load_init_policy(&self, seed: u64) -> anyhow::Result<Vec<f32>> {
        let seed = if self.init_seeds > 0 {
            seed % self.init_seeds as u64
        } else {
            0
        };
        let path = self.dir.join(format!("init_policy_seed{seed}.f32"));
        read_f32_file(&path)
    }
}

fn read_f32_file(path: &Path) -> anyhow::Result<Vec<f32>> {
    let bytes = std::fs::read(path).map_err(|e| anyhow::anyhow!("reading {path:?}: {e}"))?;
    anyhow::ensure!(bytes.len() % 4 == 0, "{path:?} not a multiple of 4 bytes");
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Default artifacts dir: `$DYNAMIX_ARTIFACTS` or `<repo>/artifacts`
/// (one level above the crate, where `make artifacts` emits).
pub fn default_artifacts_dir() -> PathBuf {
    crate::config::env::artifacts_dir_override()
        .unwrap_or_else(|| PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/../artifacts")))
}

// Loading a real manifest requires `make artifacts`, which only the XLA
// backend needs — skip cleanly on artifact-less (native) builds.
#[cfg(all(test, feature = "backend-xla"))]
mod tests {
    use super::*;

    fn manifest() -> Manifest {
        Manifest::load(&default_artifacts_dir()).expect("run `make artifacts` before cargo test")
    }

    #[test]
    fn loads_real_manifest() {
        let m = manifest();
        assert_eq!(m.state_dim, 16);
        assert_eq!(m.n_actions, 5);
        assert!(m.artifacts.len() >= 7);
        assert!(m.models.contains_key("vgg11_mini"));
    }

    #[test]
    fn bucket_for_picks_smallest_upper() {
        let m = manifest();
        assert_eq!(m.bucket_for(1).unwrap(), 32);
        assert_eq!(m.bucket_for(32).unwrap(), 32);
        assert_eq!(m.bucket_for(33).unwrap(), 64);
        let &last = m.buckets.last().unwrap();
        assert_eq!(m.bucket_for(last).unwrap(), last);
        assert!(m.bucket_for(last + 1).is_err());
    }

    #[test]
    fn train_artifact_schema_consistent() {
        let m = manifest();
        let name = m.train_artifact("vgg11_mini", "sgd", 32);
        let a = m.artifact(&name).unwrap();
        assert_eq!(a.kind, "train_step");
        assert_eq!(a.inputs.len(), 8);
        assert_eq!(a.outputs.len(), 10);
        let pc = m.model("vgg11_mini").unwrap().param_count;
        assert_eq!(a.inputs[0].elements(), pc);
        assert_eq!(a.outputs[0].elements(), pc);
        // x input is [bucket, feature_dim]
        assert_eq!(a.inputs[4].shape, vec![32, m.feature_dim]);
        // correct vector is [bucket]
        assert_eq!(a.outputs[6].shape, vec![32]);
    }

    #[test]
    fn init_snapshots_load_and_match_param_count() {
        let m = manifest();
        let p = m.load_init_params("vgg11_mini", 0).unwrap();
        assert_eq!(p.len(), m.model("vgg11_mini").unwrap().param_count);
        assert!(p.iter().all(|x| x.is_finite()));
        // seed wrap-around: seed init_seeds maps to seed 0
        let p2 = m.load_init_params("vgg11_mini", m.init_seeds as u64).unwrap();
        assert_eq!(p, p2);
        let pol = m.load_init_policy(1).unwrap();
        assert_eq!(pol.len(), m.policy_param_count);
    }

    #[test]
    fn missing_artifact_is_informative() {
        let m = manifest();
        let err = m.artifact("train_nope_sgd_b32").unwrap_err().to_string();
        assert!(err.contains("train_nope_sgd_b32"));
    }
}
