//! Lazy-compiling executable store over the PJRT CPU client (`backend-xla`).
//!
//! Compiling an HLO module takes O(100ms..s); the bucket ladder times six
//! (model, optimizer) combos would make eager startup ~a minute. The store
//! compiles on first use and caches `Arc<PjRtLoadedExecutable>` forever
//! (executables are immutable).
//!
//! Concurrency: each artifact owns a slot (`Arc<Mutex<Option<exe>>>`)
//! handed out under a short global lock. The first caller holds the slot
//! lock across its compile, so racing callers for the SAME artifact block
//! until it lands instead of compiling twice (O(100ms..s) wasted work),
//! while callers for DIFFERENT artifacts still compile concurrently.

use super::manifest::Manifest;
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

/// Decomposed outputs of a tuple-rooted executable run.
pub struct Outputs(pub Vec<Literal>);

impl std::fmt::Debug for Outputs {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Outputs({} literals)", self.0.len())
    }
}

impl Outputs {
    /// f32 vector at output index `i`.
    pub fn vec_f32(&self, i: usize) -> anyhow::Result<Vec<f32>> {
        Ok(self.0[i].to_vec::<f32>()?)
    }

    /// Scalar f32 at output index `i` (accepts [] or [1] shapes).
    pub fn scalar_f32(&self, i: usize) -> anyhow::Result<f32> {
        let v = self.0[i].to_vec::<f32>()?;
        anyhow::ensure!(!v.is_empty(), "output {i} empty");
        Ok(v[0])
    }

    /// Move the literal at index `i` out (for carrying state across steps).
    pub fn take(&mut self, i: usize) -> Literal {
        std::mem::replace(&mut self.0[i], Literal::vec1::<f32>(&[]))
    }
}

type Slot = Arc<Mutex<Option<Arc<PjRtLoadedExecutable>>>>;

/// Compile-and-cache store for every artifact in the manifest.
pub struct ArtifactStore {
    pub client: PjRtClient,
    pub manifest: Manifest,
    /// Keyed by artifact name; `BTreeMap` so any future iteration
    /// (compiled counts, log dumps) walks artifacts in a deterministic
    /// order — the `nondet-collection` lint forbids `HashMap` here.
    slots: Mutex<BTreeMap<String, Slot>>,
    /// (artifact, compile_seconds) log for EXPERIMENTS.md §Perf.
    compile_log: Mutex<Vec<(String, f64)>>,
}

impl ArtifactStore {
    /// Open the store over `dir` (must contain manifest.json).
    pub fn open(dir: &Path) -> anyhow::Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu client: {e}"))?;
        Ok(ArtifactStore {
            client,
            manifest,
            slots: Mutex::new(BTreeMap::new()),
            compile_log: Mutex::new(Vec::new()),
        })
    }

    /// Open at the default artifacts location.
    pub fn open_default() -> anyhow::Result<Self> {
        Self::open(&super::manifest::default_artifacts_dir())
    }

    /// Get (lazily compiling) the executable for `name`. Concurrent callers
    /// of the same artifact serialize on its slot: exactly one compiles,
    /// the rest wait and reuse the result.
    pub fn get(&self, name: &str) -> anyhow::Result<Arc<PjRtLoadedExecutable>> {
        let slot: Slot = {
            let mut slots = self.slots.lock().unwrap();
            slots.entry(name.to_string()).or_default().clone()
        };
        let mut guard = slot.lock().unwrap();
        if let Some(exe) = guard.as_ref() {
            return Ok(exe.clone());
        }
        let meta = self.manifest.artifact(name)?;
        let path = self.manifest.dir.join(&meta.file);
        let t0 = Instant::now();
        let proto = HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parsing {path:?}: {e}"))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = Arc::new(
            self.client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {name}: {e}"))?,
        );
        let dt = t0.elapsed().as_secs_f64();
        self.compile_log.lock().unwrap().push((name.to_string(), dt));
        *guard = Some(exe.clone());
        Ok(exe)
    }

    /// Execute artifact `name` with literal args; decompose the tuple root.
    /// Accepts owned literals or references (`&[&Literal]`).
    pub fn run<L: std::borrow::Borrow<Literal>>(
        &self,
        name: &str,
        args: &[L],
    ) -> anyhow::Result<Outputs> {
        let meta_inputs = self.manifest.artifact(name)?.inputs.len();
        anyhow::ensure!(
            args.len() == meta_inputs,
            "{name}: {} args given, manifest says {meta_inputs}",
            args.len()
        );
        let exe = self.get(name)?;
        let result = exe
            .execute(args)
            .map_err(|e| anyhow::anyhow!("executing {name}: {e}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching {name} outputs: {e}"))?;
        let parts = tuple
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling {name} outputs: {e}"))?;
        let n_out = self.manifest.artifact(name)?.outputs.len();
        anyhow::ensure!(
            parts.len() == n_out,
            "{name}: {} outputs, manifest says {n_out}",
            parts.len()
        );
        Ok(Outputs(parts))
    }

    /// Number of executables compiled so far (for tests/overhead reports).
    /// Snapshots the slot handles before inspecting them so an in-flight
    /// compile (which holds its slot lock) never blocks this call — and
    /// this call never holds the global lock across slot locks, which
    /// would stall unrelated `get()`s. A slot whose compile is still in
    /// flight counts as not-yet-compiled.
    pub fn compiled_count(&self) -> usize {
        let slots: Vec<Slot> = self.slots.lock().unwrap().values().cloned().collect();
        slots
            .iter()
            .filter(|s| s.try_lock().map(|g| g.is_some()).unwrap_or(false))
            .count()
    }

    /// Snapshot of the compile log: (artifact, seconds).
    pub fn compile_log(&self) -> Vec<(String, f64)> {
        self.compile_log.lock().unwrap().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store() -> ArtifactStore {
        ArtifactStore::open_default().expect("run `make artifacts` before cargo test")
    }

    #[test]
    fn compiles_lazily_and_caches() {
        let s = store();
        assert_eq!(s.compiled_count(), 0);
        let _e1 = s.get("policy_forward").unwrap();
        assert_eq!(s.compiled_count(), 1);
        let _e2 = s.get("policy_forward").unwrap();
        assert_eq!(s.compiled_count(), 1);
        assert_eq!(s.compile_log().len(), 1);
    }

    #[test]
    fn concurrent_get_compiles_exactly_once() {
        let s = std::sync::Arc::new(store());
        let mut handles = Vec::new();
        for _ in 0..8 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                s.get("policy_forward").unwrap();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.compile_log().len(), 1, "in-flight guard failed: double compile");
        assert_eq!(s.compiled_count(), 1);
    }

    #[test]
    fn run_checks_arity() {
        let s = store();
        let empty: [&Literal; 0] = [];
        let err = s.run("policy_forward", &empty).unwrap_err().to_string();
        assert!(err.contains("manifest says"), "{err}");
    }
}
