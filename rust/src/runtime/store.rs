//! Lazy-compiling executable store over the PJRT CPU client.
//!
//! Compiling an HLO module takes O(100ms..s); the bucket ladder times six
//! (model, optimizer) combos would make eager startup ~a minute. The store
//! compiles on first use and caches `Arc<PjRtLoadedExecutable>` forever
//! (executables are immutable). A `Mutex<HashMap>` is fine: the hot loop
//! hits the cache once per iteration and the critical section is a clone.

use super::manifest::Manifest;
use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

/// Decomposed outputs of a tuple-rooted executable run.
pub struct Outputs(pub Vec<Literal>);

impl std::fmt::Debug for Outputs {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Outputs({} literals)", self.0.len())
    }
}

impl Outputs {
    /// f32 vector at output index `i`.
    pub fn vec_f32(&self, i: usize) -> anyhow::Result<Vec<f32>> {
        Ok(self.0[i].to_vec::<f32>()?)
    }

    /// Scalar f32 at output index `i` (accepts [] or [1] shapes).
    pub fn scalar_f32(&self, i: usize) -> anyhow::Result<f32> {
        let v = self.0[i].to_vec::<f32>()?;
        anyhow::ensure!(!v.is_empty(), "output {i} empty");
        Ok(v[0])
    }

    /// Move the literal at index `i` out (for carrying state across steps).
    pub fn take(&mut self, i: usize) -> Literal {
        std::mem::replace(&mut self.0[i], Literal::vec1::<f32>(&[]))
    }
}

/// Compile-and-cache store for every artifact in the manifest.
pub struct ArtifactStore {
    pub client: PjRtClient,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<PjRtLoadedExecutable>>>,
    /// (artifact, compile_seconds) log for EXPERIMENTS.md §Perf.
    compile_log: Mutex<Vec<(String, f64)>>,
}

impl ArtifactStore {
    /// Open the store over `dir` (must contain manifest.json).
    pub fn open(dir: &Path) -> anyhow::Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu client: {e}"))?;
        Ok(ArtifactStore {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
            compile_log: Mutex::new(Vec::new()),
        })
    }

    /// Open at the default artifacts location.
    pub fn open_default() -> anyhow::Result<Self> {
        Self::open(&super::manifest::default_artifacts_dir())
    }

    /// Get (lazily compiling) the executable for `name`.
    pub fn get(&self, name: &str) -> anyhow::Result<Arc<PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let meta = self.manifest.artifact(name)?;
        let path = self.manifest.dir.join(&meta.file);
        let t0 = Instant::now();
        let proto = HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parsing {path:?}: {e}"))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = Arc::new(
            self.client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {name}: {e}"))?,
        );
        let dt = t0.elapsed().as_secs_f64();
        self.compile_log.lock().unwrap().push((name.to_string(), dt));
        // Racing compilers of the same artifact: last wins, both valid.
        self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute artifact `name` with literal args; decompose the tuple root.
    /// Accepts owned literals or references (`&[&Literal]`).
    pub fn run<L: std::borrow::Borrow<Literal>>(
        &self,
        name: &str,
        args: &[L],
    ) -> anyhow::Result<Outputs> {
        let meta_inputs = self.manifest.artifact(name)?.inputs.len();
        anyhow::ensure!(
            args.len() == meta_inputs,
            "{name}: {} args given, manifest says {meta_inputs}",
            args.len()
        );
        let exe = self.get(name)?;
        let result = exe
            .execute(args)
            .map_err(|e| anyhow::anyhow!("executing {name}: {e}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching {name} outputs: {e}"))?;
        let parts = tuple
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling {name} outputs: {e}"))?;
        let n_out = self.manifest.artifact(name)?.outputs.len();
        anyhow::ensure!(
            parts.len() == n_out,
            "{name}: {} outputs, manifest says {n_out}",
            parts.len()
        );
        Ok(Outputs(parts))
    }

    /// Number of executables compiled so far (for tests/overhead reports).
    pub fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Snapshot of the compile log: (artifact, seconds).
    pub fn compile_log(&self) -> Vec<(String, f64)> {
        self.compile_log.lock().unwrap().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{lit_f32, lit_i32, lit_scalar1};

    fn store() -> ArtifactStore {
        ArtifactStore::open_default().expect("run `make artifacts` before cargo test")
    }

    #[test]
    fn compiles_lazily_and_caches() {
        let s = store();
        assert_eq!(s.compiled_count(), 0);
        let _e1 = s.get("policy_forward").unwrap();
        assert_eq!(s.compiled_count(), 1);
        let _e2 = s.get("policy_forward").unwrap();
        assert_eq!(s.compiled_count(), 1);
        assert_eq!(s.compile_log().len(), 1);
    }

    #[test]
    fn run_train_step_decreases_loss_on_fixed_batch() {
        let s = store();
        let m = &s.manifest;
        let name = m.train_artifact("vgg11_mini", "sgd", 32);
        let pc = m.model("vgg11_mini").unwrap().param_count;
        let fd = m.feature_dim;

        let mut params = lit_f32(&m.load_init_params("vgg11_mini", 0).unwrap(), &[pc as i64]).unwrap();
        let mut mom = lit_f32(&vec![0.0; pc], &[pc as i64]).unwrap();
        let mut vv = lit_scalar1(0.0);
        let mut step = lit_scalar1(0.0);

        // Deterministic learnable batch: y = argmax over 10 fixed projections.
        let mut rng = crate::util::rng::Rng::new(9);
        let x: Vec<f32> = (0..32 * fd).map(|_| rng.normal() as f32).collect();
        let proto: Vec<f32> = (0..10 * fd).map(|_| rng.normal() as f32).collect();
        let y: Vec<i32> = (0..32)
            .map(|i| {
                (0..10)
                    .max_by(|&a, &b| {
                        let da: f32 = (0..fd).map(|j| x[i * fd + j] * proto[a * fd + j]).sum();
                        let db: f32 = (0..fd).map(|j| x[i * fd + j] * proto[b * fd + j]).sum();
                        da.partial_cmp(&db).unwrap()
                    })
                    .unwrap() as i32
            })
            .collect();
        let xl = lit_f32(&x, &[32, fd as i64]).unwrap();
        let yl = lit_i32(&y, &[32]).unwrap();
        let mask = lit_f32(&vec![1.0; 32], &[32]).unwrap();
        let lr = lit_scalar1(0.05);

        let mut losses = Vec::new();
        for _ in 0..25 {
            let mut out = s
                .run(&name, &[&params, &mom, &vv, &step, &xl, &yl, &mask, &lr])
                .unwrap();
            losses.push(out.scalar_f32(4).unwrap());
            params = out.take(0);
            mom = out.take(1);
            vv = out.take(2);
            step = out.take(3);
        }
        assert!(losses.iter().all(|l| l.is_finite()));
        assert!(
            losses[24] < losses[0] * 0.8,
            "loss did not decrease: {losses:?}"
        );
    }

    #[test]
    fn run_checks_arity() {
        let s = store();
        let empty: [&Literal; 0] = [];
        let err = s.run("policy_forward", &empty).unwrap_err().to_string();
        assert!(err.contains("manifest says"), "{err}");
    }

    #[test]
    fn policy_forward_logprobs_normalized() {
        let s = store();
        let m = &s.manifest;
        let theta = lit_f32(&m.load_init_policy(0).unwrap(), &[m.policy_param_count as i64]).unwrap();
        let states = lit_f32(
            &vec![0.1; m.max_workers * m.state_dim],
            &[m.max_workers as i64, m.state_dim as i64],
        )
        .unwrap();
        let out = s.run("policy_forward", &[theta, states]).unwrap();
        let logp = out.vec_f32(0).unwrap();
        assert_eq!(logp.len(), m.max_workers * m.n_actions);
        for w in 0..m.max_workers {
            let total: f32 = (0..m.n_actions)
                .map(|a| logp[w * m.n_actions + a].exp())
                .sum();
            assert!((total - 1.0).abs() < 1e-4, "worker {w}: {total}");
        }
    }
}
