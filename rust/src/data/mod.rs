//! Synthetic CIFAR-like datasets + distributed sharding.
//!
//! The paper trains on CIFAR-10/100; this environment has no dataset
//! downloads, so we substitute deterministic procedural datasets that
//! preserve the learning-dynamics properties the experiments depend on
//! (DESIGN.md substitution table):
//!
//! * classes are separable but not linearly trivial — each sample mixes a
//!   class prototype, a *signed nonlinear* second-order term, and noise,
//!   so deeper models gain accuracy and training takes many SGD steps;
//! * accuracy rises smoothly with steps, and gradient noise scales with
//!   1/sqrt(batch) — the statistical-efficiency side of the paper's
//!   batch-size trade-off emerges rather than being scripted;
//! * samples are a pure function of (dataset seed, index): no files, no
//!   state, identical across workers, epochs reshuffle index order only.
//!
//! [`ShardSampler`] mirrors PyTorch's `DistributedSampler`: each worker
//! draws a disjoint, epoch-shuffled strided shard of the index space.

use crate::util::rng::Rng;

/// Deterministic procedural classification dataset.
pub struct SyntheticDataset {
    pub num_classes: usize,
    pub feature_dim: usize,
    pub train_size: usize,
    seed: u64,
    /// Class prototypes, row-major [num_classes, feature_dim].
    prototypes: Vec<f32>,
    /// Secondary prototypes for the nonlinear term.
    prototypes2: Vec<f32>,
}

/// Dataset flavour matching a model's `dataset` manifest field.
pub fn by_name(name: &str, feature_dim: usize, seed: u64) -> anyhow::Result<SyntheticDataset> {
    match name {
        "cifar10_syn" => Ok(SyntheticDataset::new(10, feature_dim, 50_000, seed)),
        "cifar100_syn" => Ok(SyntheticDataset::new(100, feature_dim, 50_000, seed)),
        _ => anyhow::bail!("unknown dataset {name:?}"),
    }
}

impl SyntheticDataset {
    pub fn new(num_classes: usize, feature_dim: usize, train_size: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0xD474_5E7);
        let mut proto = |n: usize| -> Vec<f32> {
            (0..n).map(|_| rng.normal() as f32).collect()
        };
        let prototypes = proto(num_classes * feature_dim);
        let prototypes2 = proto(num_classes * feature_dim);
        SyntheticDataset {
            num_classes,
            feature_dim,
            train_size,
            seed,
            prototypes,
            prototypes2,
        }
    }

    /// Generate sample `index` into `x` (len feature_dim); returns label.
    ///
    /// Index space: [0, train_size) is training data; indices >= train_size
    /// form the held-out eval stream (same generator, disjoint randomness).
    pub fn sample_into(&self, index: u64, x: &mut [f32]) -> i32 {
        assert_eq!(x.len(), self.feature_dim);
        let mut rng = Rng::new(self.seed ^ 0x5A17).split(index);
        let y = rng.below(self.num_classes);
        // Label noise caps achievable accuracy below 1.0 (CIFAR-like
        // ceilings: ~0.92 for 10-class, ~0.85 for 100-class), so the
        // paper's accuracy-vs-batch-size gaps have headroom to show.
        let noise_p = if self.num_classes > 10 { 0.15 } else { 0.08 };
        let y_label = if rng.uniform() < noise_p {
            rng.below(self.num_classes)
        } else {
            y
        };
        let p = &self.prototypes[y * self.feature_dim..(y + 1) * self.feature_dim];
        let p2 = &self.prototypes2[y * self.feature_dim..(y + 1) * self.feature_dim];
        // Per-sample latent style factors.
        let a = 0.8 + 0.4 * rng.uniform() as f32;
        let b = rng.normal() as f32;
        // Difficulty scales with class count (CIFAR-100 is harder).
        let noise_scale = if self.num_classes > 10 { 1.4 } else { 1.6 };
        for i in 0..self.feature_dim {
            let nonlinear = (p2[i] * b).tanh(); // signed second-order term
            x[i] = a * p[i] + 0.9 * nonlinear + noise_scale * rng.normal() as f32;
        }
        y_label as i32
    }

    /// Allocate-and-fill a batch of samples by raw indices.
    pub fn batch(&self, indices: &[u64]) -> (Vec<f32>, Vec<i32>) {
        let mut xs = vec![0.0f32; indices.len() * self.feature_dim];
        let mut ys = vec![0i32; indices.len()];
        for (row, &idx) in indices.iter().enumerate() {
            ys[row] =
                self.sample_into(idx, &mut xs[row * self.feature_dim..(row + 1) * self.feature_dim]);
        }
        (xs, ys)
    }

    /// Fixed held-out eval batch (indices beyond the training range).
    pub fn eval_batch(&self, n: usize) -> (Vec<f32>, Vec<i32>) {
        let indices: Vec<u64> = (0..n as u64).map(|i| self.train_size as u64 + i).collect();
        self.batch(&indices)
    }
}

/// `DistributedSampler`-equivalent: disjoint epoch-shuffled shards.
///
/// Worker `w` of `n` draws the indices at positions `w, w+n, w+2n, ...` of
/// an epoch-seeded permutation of `[0, train_size)`. Like the PyTorch
/// sampler, the permutation depends only on (seed, epoch), so every worker
/// can compute its shard locally with zero coordination.
pub struct ShardSampler {
    pub worker: usize,
    pub n_workers: usize,
    pub train_size: usize,
    seed: u64,
    epoch: u64,
    perm: Vec<u32>,
    cursor: usize,
}

impl ShardSampler {
    pub fn new(worker: usize, n_workers: usize, train_size: usize, seed: u64) -> Self {
        assert!(worker < n_workers);
        let mut s = ShardSampler {
            worker,
            n_workers,
            train_size,
            seed,
            epoch: 0,
            perm: Vec::new(),
            cursor: 0,
        };
        s.reshuffle();
        s
    }

    fn reshuffle(&mut self) {
        if self.perm.is_empty() {
            self.perm = (0..self.train_size as u32).collect();
        }
        let mut rng = Rng::new(self.seed ^ 0x5A3D_1E25).split(self.epoch);
        // Identical permutation on every worker for this epoch.
        let mut full: Vec<u32> = (0..self.train_size as u32).collect();
        rng.shuffle(&mut full);
        self.perm = full;
        self.cursor = self.worker;
    }

    /// Current epoch number (increments when a shard wraps).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Checkpoint image. The permutation itself is NOT captured — it is a
    /// pure function of `(seed, epoch)` and is rebuilt on restore.
    pub fn snapshot(&self) -> SamplerState {
        SamplerState {
            worker: self.worker,
            n_workers: self.n_workers,
            train_size: self.train_size,
            seed: self.seed,
            epoch: self.epoch,
            cursor: self.cursor,
        }
    }

    /// Rebuild a sampler mid-epoch: reshuffles for the stored epoch, then
    /// places the cursor exactly where the snapshot left it.
    pub fn from_snapshot(s: &SamplerState) -> Self {
        let mut sampler = ShardSampler::new(s.worker, s.n_workers, s.train_size, s.seed);
        sampler.epoch = s.epoch;
        sampler.reshuffle();
        sampler.cursor = s.cursor;
        sampler
    }

    /// Draw the next `n` indices for this worker's shard; wraps epochs.
    pub fn next_indices(&mut self, n: usize, out: &mut Vec<u64>) {
        out.clear();
        for _ in 0..n {
            if self.cursor >= self.perm.len() {
                self.epoch += 1;
                self.reshuffle();
            }
            out.push(self.perm[self.cursor] as u64);
            self.cursor += self.n_workers;
        }
    }
}

/// Serializable checkpoint image of a [`ShardSampler`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SamplerState {
    pub worker: usize,
    pub n_workers: usize,
    pub train_size: usize,
    pub seed: u64,
    pub epoch: u64,
    pub cursor: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_deterministic() {
        let d = SyntheticDataset::new(10, 128, 1000, 7);
        let mut a = vec![0.0; 128];
        let mut b = vec![0.0; 128];
        let ya = d.sample_into(42, &mut a);
        let yb = d.sample_into(42, &mut b);
        assert_eq!(ya, yb);
        assert_eq!(a, b);
        let yc = d.sample_into(43, &mut b);
        assert!(a != b || ya != yc);
    }

    #[test]
    fn labels_cover_classes_roughly_uniform() {
        let d = SyntheticDataset::new(10, 128, 1000, 1);
        let mut counts = [0usize; 10];
        let mut x = vec![0.0; 128];
        for i in 0..5000 {
            counts[d.sample_into(i, &mut x) as usize] += 1;
        }
        for (c, &n) in counts.iter().enumerate() {
            assert!(n > 300 && n < 700, "class {c}: {n}");
        }
    }

    #[test]
    fn classes_are_linearly_detectable_but_noisy() {
        // Nearest-prototype classification should beat chance clearly but
        // not saturate — that's the regime where training dynamics matter.
        let d = SyntheticDataset::new(10, 128, 1000, 3);
        let mut x = vec![0.0; 128];
        let mut correct = 0;
        let n = 2000;
        for i in 0..n {
            let y = d.sample_into(i, &mut x) as usize;
            let best = (0..10)
                .max_by(|&a, &b| {
                    let da: f32 = (0..128)
                        .map(|j| x[j] * d.prototypes[a * 128 + j])
                        .sum();
                    let db: f32 = (0..128)
                        .map(|j| x[j] * d.prototypes[b * 128 + j])
                        .sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best == y {
                correct += 1;
            }
        }
        let acc = correct as f64 / n as f64;
        assert!(acc > 0.25, "prototype acc too low: {acc}");
        assert!(acc < 0.97, "dataset trivially separable: {acc}");
    }

    #[test]
    fn eval_batch_disjoint_from_train() {
        let d = SyntheticDataset::new(10, 128, 100, 5);
        let (xs, _) = d.eval_batch(4);
        let (xt, _) = d.batch(&[0, 1, 2, 3]);
        assert_ne!(xs, xt);
    }

    #[test]
    fn shards_are_disjoint_and_cover() {
        let size = 997; // prime: exercises uneven tails
        let n_workers = 4;
        let mut seen = vec![0u8; size];
        let mut total = 0;
        for w in 0..n_workers {
            let mut s = ShardSampler::new(w, n_workers, size, 11);
            let mut idx = Vec::new();
            // Draw strictly less than one epoch per worker.
            s.next_indices(size / n_workers, &mut idx);
            for &i in &idx {
                seen[i as usize] += 1;
                total += 1;
            }
        }
        assert!(seen.iter().all(|&c| c <= 1), "overlapping shards");
        assert_eq!(total, (size / n_workers) * n_workers);
    }

    #[test]
    fn epochs_reshuffle() {
        let size = 64;
        let mut s = ShardSampler::new(0, 1, size, 2);
        let mut e0 = Vec::new();
        let mut e1 = Vec::new();
        s.next_indices(size, &mut e0);
        assert_eq!(s.epoch(), 0);
        s.next_indices(size, &mut e1);
        assert_eq!(s.epoch(), 1);
        assert_ne!(e0, e1);
        let mut s0: Vec<_> = e0.clone();
        let mut s1: Vec<_> = e1.clone();
        s0.sort_unstable();
        s1.sort_unstable();
        assert_eq!(s0, s1, "each epoch is a permutation of the same set");
    }

    #[test]
    fn sampler_snapshot_resumes_mid_epoch_bitwise() {
        let mut s = ShardSampler::new(1, 4, 997, 13);
        let mut scratch = Vec::new();
        // Burn past an epoch boundary so epoch > 0 and the cursor is deep.
        for _ in 0..9 {
            s.next_indices(40, &mut scratch);
        }
        let snap = s.snapshot();
        let mut want = Vec::new();
        for _ in 0..8 {
            s.next_indices(40, &mut scratch);
            want.extend_from_slice(&scratch);
        }
        let mut r = ShardSampler::from_snapshot(&snap);
        assert_eq!(r.epoch(), snap.epoch);
        let mut got = Vec::new();
        for _ in 0..8 {
            r.next_indices(40, &mut scratch);
            got.extend_from_slice(&scratch);
        }
        assert_eq!(got, want);
    }

    #[test]
    fn cifar100_syn_is_harder() {
        let d10 = by_name("cifar10_syn", 128, 0).unwrap();
        let d100 = by_name("cifar100_syn", 128, 0).unwrap();
        assert_eq!(d10.num_classes, 10);
        assert_eq!(d100.num_classes, 100);
        assert!(by_name("imagenet", 128, 0).is_err());
    }
}
