//! The DYNAMIX arbitrator (paper §III-C, §V, Algorithm 1).
//!
//! Ties the BSP trainer to the PPO agent in the paper's cyclic protocol:
//! train `k` iterations per worker, aggregate each worker's window into a
//! state vector, score all workers with one `policy_forward` call, apply
//! the batch-size deltas under the [32,1024] + memory constraints, repeat.
//!
//! Credit assignment follows Algorithm 1: the reward for the action taken
//! at cycle `c` is computed from the *next* window (the k iterations run
//! under the adjusted batch sizes), so each transition is (s_c, a_c,
//! r_{c+1}). An episode of `steps_per_episode` decision steps therefore
//! spans `steps_per_episode + 1` windows.
//!
//! Two modes:
//! * [`Coordinator::train_rl`]       — episodic PPO training (§VI-C):
//!   model/cluster reset each episode, exploration on, policy updated from
//!   the episode's trajectories.
//! * [`Coordinator::run_inference`]  — frozen-policy deployment (§VI-D):
//!   greedy actions, runs to convergence or the step cap, records the
//!   trajectory.

use crate::ckpt::{self, CkptHeader, CycleSnap, Journal, ResumeState};
use crate::config::{env, ExperimentConfig};
use crate::metrics::{mean_std, mean_std_usize, median, ConvergenceDetector, RunRecord, TracePoint};
use crate::rl::action::BatchRule;
use crate::rl::agent::{PpoAgent, UpdateStats};
use crate::rl::reward::RewardParams;
use crate::rl::state::{GlobalState, StateBuilder, StateVector, STATE_DIM};
use crate::rl::trajectory::{Trajectory, Transition, UpdateBatch};
use crate::runtime::Backend;
use crate::trainer::BspTrainer;

/// Outcome of one k-iteration decision cycle (pre-action snapshot).
///
/// Under elastic membership (scripted preemption) an absent worker's state
/// vector is the zero mask and its reward is 0; `active[w]` tells callers
/// which entries are real.
#[derive(Clone, Debug)]
pub struct CycleOutcome {
    pub states: Vec<StateVector>,
    pub rewards: Vec<f64>,
    /// Membership at the end of the cycle (aligned with `states`).
    pub active: Vec<bool>,
    pub sim_clock: f64,
    pub train_acc: f64,
    pub eval_acc: f64,
    pub loss: f64,
}

/// Per-episode summary (feeds Fig. 3).
#[derive(Clone, Debug)]
pub struct EpisodeResult {
    pub episode: usize,
    /// Cumulative reward per worker.
    pub worker_returns: Vec<f64>,
    pub mean_return: f64,
    pub median_return: f64,
    pub final_train_acc: f64,
    pub final_eval_acc: f64,
    pub sim_time: f64,
    pub update: UpdateStats,
}

/// Inference-run summary (feeds Fig. 4/5, Tables).
#[derive(Clone, Debug)]
pub struct InferenceSummary {
    pub final_eval_acc: f64,
    pub best_eval_acc: f64,
    pub convergence_time: Option<f64>,
    pub total_sim_time: f64,
    pub total_iters: usize,
    /// (cycle, per-worker batch mean, std) trace for Fig. 5.
    pub batch_trace: Vec<(usize, f64, f64)>,
}

pub struct Coordinator {
    pub trainer: BspTrainer,
    pub agent: PpoAgent,
    pub cfg: ExperimentConfig,
    state_builder: StateBuilder,
    reward: RewardParams,
    rule: BatchRule,
    eval_history: Vec<f64>,
    calibrated: bool,
    /// Durable-run policy (env-seeded: `DYNAMIX_CKPT_DIR` / `_EVERY` /
    /// `_KEEP` / `_RESUME`; overridable via [`Coordinator::set_ckpt_policy`]).
    ckpt_dir: Option<std::path::PathBuf>,
    ckpt_every: usize,
    /// Retention: prune to the newest k images after each save (`None`
    /// keeps everything).
    ckpt_keep: Option<usize>,
    resume: bool,
}

impl Coordinator {
    pub fn new(cfg: ExperimentConfig, backend: Backend) -> anyhow::Result<Self> {
        cfg.validate()?;
        let mut trainer = BspTrainer::new(&cfg, backend.clone())?;
        trainer.calibrate()?;
        let agent = PpoAgent::new(backend, cfg.rl.clone(), cfg.train.seed)?;
        let state_builder = StateBuilder {
            use_network_features: cfg.rl.use_network_features,
            use_grad_stats_features: cfg.rl.use_grad_stats_features,
            iter_time_ref: 0.1, // recalibrated from the first window
        };
        let reward = RewardParams {
            alpha: cfg.rl.alpha,
            beta: cfg.rl.beta,
            delta: cfg.rl.delta,
            eta: cfg.rl.eta,
            adaptive: cfg.train.optimizer.is_adaptive(),
            iter_time_ref: 0.1,
        };
        let rule = BatchRule {
            min: cfg.batch.min,
            max: cfg.batch.max,
        };
        Ok(Coordinator {
            trainer,
            agent,
            cfg,
            state_builder,
            reward,
            rule,
            eval_history: Vec::new(),
            calibrated: false,
            ckpt_dir: env::ckpt_dir(),
            ckpt_every: env::ckpt_every().unwrap_or(1),
            ckpt_keep: env::ckpt_keep(),
            resume: env::resume(),
        })
    }

    /// Enable (or disable) durable-run checkpointing: write one image to
    /// `dir` every `every` decision cycles. Overrides the env-seeded
    /// policy; tests and the CLI use this rather than mutating the
    /// process environment.
    pub fn set_ckpt_policy(&mut self, dir: Option<std::path::PathBuf>, every: usize) {
        self.ckpt_dir = dir;
        self.ckpt_every = every.max(1);
    }

    /// Retention policy: keep only the newest `keep` checkpoint images
    /// after each save (`None` disables pruning). Overrides
    /// `DYNAMIX_CKPT_KEEP`.
    pub fn set_ckpt_keep(&mut self, keep: Option<usize>) {
        self.ckpt_keep = keep.map(|k| k.max(1));
    }

    /// Request that the next [`Coordinator::run_inference`] resume from
    /// the latest checkpoint under the configured directory.
    pub fn set_resume(&mut self, on: bool) {
        self.resume = on;
    }

    /// Deployment fingerprint stamped into every checkpoint image; a
    /// resume under a different plane/wire/seed/worker-count/model is
    /// rejected loudly at load.
    fn ckpt_header(&self) -> CkptHeader {
        CkptHeader {
            plane: env::plane().unwrap_or_else(|| "zero".into()),
            wire: self.trainer.wire_label().to_string(),
            seed: self.cfg.train.seed,
            n_workers: self.cfg.cluster.n_workers,
            model: self.cfg.train.model.clone(),
        }
    }

    /// Capture everything a resumed run needs to continue bit-for-bit:
    /// trainer (model/optimizer, cluster + fabric RNG streams, samplers,
    /// remaining scenario timeline), agent, detector, calibration refs and
    /// the record-so-far, plus the pending cycle outcome.
    fn capture(
        &self,
        step: usize,
        detector: &ConvergenceDetector,
        record: &RunRecord,
        cycle: &CycleOutcome,
    ) -> ResumeState {
        ResumeState {
            step,
            trainer: self.trainer.snapshot(),
            agent: self.agent.snapshot(),
            detector: detector.snapshot(),
            eval_history: self.eval_history.clone(),
            calibrated: self.calibrated,
            state_iter_time_ref: self.state_builder.iter_time_ref,
            reward_iter_time_ref: self.reward.iter_time_ref,
            record: record.clone(),
            cycle: CycleSnap {
                states: cycle.states.iter().map(|s| s.0.clone()).collect(),
                rewards: cycle.rewards.clone(),
                active: cycle.active.clone(),
                sim_clock: cycle.sim_clock,
                train_acc: cycle.train_acc,
                eval_acc: cycle.eval_acc,
                loss: cycle.loss,
            },
        }
    }

    /// Run k training iterations and summarize every worker's window.
    fn run_cycle(&mut self, progress: f64) -> anyhow::Result<CycleOutcome> {
        let k = self.cfg.rl.k;
        let mut last_acc = 0.0;
        let mut last_loss = 0.0;
        for _ in 0..k {
            let out = self.trainer.iterate()?;
            last_acc = out.acc;
            last_loss = out.loss;
        }
        let (_, eval_acc) = self.trainer.eval()?;
        self.eval_history.push(eval_acc);
        let eval_trend = if self.eval_history.len() >= 2 {
            let n = self.eval_history.len();
            self.eval_history[n - 1] - self.eval_history[n - 2]
        } else {
            0.0
        };
        let global = GlobalState {
            loss: last_loss,
            eval_acc,
            eval_trend,
            progress,
            // The policy's scale feature tracks the LIVE cluster size, so
            // preemption is visible in every worker's state.
            n_workers: self.trainer.n_active(),
        };
        let n = self.trainer.n_workers();
        let active = self.trainer.active_mask();
        let mut states = Vec::with_capacity(n);
        let mut rewards = Vec::with_capacity(n);
        for w in 0..n {
            // Absent workers are masked: zero state, zero reward. finish()
            // still runs to clear any partial pre-preemption window.
            let summary = self.trainer.windows[w].finish();
            if !active[w] {
                rewards.push(0.0);
                states.push(StateVector(vec![0.0; STATE_DIM]));
                continue;
            }
            if !self.calibrated && summary.iter_time_mean > 0.0 {
                // First window defines the iteration-time reference for
                // both the state feature and the reward's beta term.
                self.state_builder.iter_time_ref = summary.iter_time_mean;
                self.reward.iter_time_ref = summary.iter_time_mean;
                self.calibrated = true;
            }
            rewards.push(self.reward.compute(&summary, self.trainer.batches[w]));
            states.push(self.state_builder.build(&summary, self.trainer.batches[w], &global));
        }
        Ok(CycleOutcome {
            states,
            rewards,
            active,
            sim_clock: self.trainer.cluster.clock,
            train_acc: last_acc,
            eval_acc,
            loss: last_loss,
        })
    }

    /// Apply one action per worker under batch + memory constraints.
    /// Absent workers take no action (their frozen batch waits for rejoin).
    fn apply_actions(&mut self, actions: &[usize]) {
        let max = self.cfg.batch.max;
        for (w, &a) in actions.iter().enumerate() {
            if !self.trainer.is_active(w) {
                continue;
            }
            let cap = self.trainer.mem_cap(w, max);
            self.trainer.batches[w] = self.rule.apply(self.trainer.batches[w], a, Some(cap));
        }
    }

    /// Episodic PPO training (§VI-C). Returns one result per episode.
    pub fn train_rl(&mut self, episodes: usize) -> anyhow::Result<Vec<EpisodeResult>> {
        let steps = self.cfg.steps_per_episode;
        let mut results = Vec::with_capacity(episodes);
        for ep in 0..episodes {
            let seed = self.cfg.train.seed ^ (ep as u64).wrapping_mul(0x9E37_79B9);
            self.trainer.reset_episode(seed, self.cfg.batch.initial)?;
            self.eval_history.clear();
            self.calibrated = false;

            let n = self.trainer.n_workers();
            let mut trajs: Vec<Trajectory> = vec![Trajectory::default(); n];
            // Window 0: state only (no action taken yet).
            let mut cycle = self.run_cycle(0.0)?;
            let mut pending: Option<Vec<crate::rl::agent::ActionSample>> = None;
            let mut last = cycle.clone();

            for step in 0..steps {
                let samples = self.agent.act(&cycle.states, true)?;
                self.apply_actions(&samples.iter().map(|s| s.action).collect::<Vec<_>>());
                let next = self.run_cycle((step + 1) as f64 / steps as f64)?;
                for w in 0..n {
                    // Only learn from real decisions: a worker absent at
                    // action time contributed a masked state and no action
                    // was applied, so no transition is recorded.
                    if !cycle.active[w] {
                        continue;
                    }
                    trajs[w].push(Transition {
                        state: cycle.states[w].clone(),
                        action: samples[w].action,
                        logp: samples[w].logp,
                        value: samples[w].value,
                        reward: next.rewards[w],
                    });
                }
                pending = Some(samples);
                last = next.clone();
                cycle = next;
            }
            drop(pending);

            let batch = UpdateBatch::from_trajectories(&trajs, self.cfg.rl.gamma, self.cfg.rl.gae_lambda);
            let update = self.agent.update(&batch)?;
            let worker_returns: Vec<f64> = trajs.iter().map(|t| t.total_reward()).collect();
            let (mean_return, _) = mean_std(&worker_returns);
            results.push(EpisodeResult {
                episode: ep,
                median_return: median(&worker_returns),
                mean_return,
                worker_returns,
                final_train_acc: last.train_acc,
                final_eval_acc: last.eval_acc,
                sim_time: last.sim_clock,
                update,
            });
        }
        Ok(results)
    }

    /// Frozen-policy inference run (§VI-D): greedy actions until the
    /// convergence target is sustained or `max_cycles` elapse.
    ///
    /// With a checkpoint directory configured, the run is **durable**: an
    /// image is written atomically every `ckpt_every` cycles (at the TOP
    /// of the cycle, before its trace point lands in `record`) and every
    /// cycle/scenario-event/checkpoint appends a sim-time-stamped line to
    /// the run journal. Under `resume`, the latest image is loaded, the
    /// deployment fingerprint checked, and the loop re-entered exactly
    /// where the image was taken — the resumed record is bit-for-bit the
    /// uninterrupted one.
    pub fn run_inference(
        &mut self,
        max_cycles: usize,
        record: &mut RunRecord,
    ) -> anyhow::Result<InferenceSummary> {
        let journal = match &self.ckpt_dir {
            Some(dir) => Some(Journal::open(dir)?),
            None => None,
        };
        let restored = if self.resume {
            let dir = self.ckpt_dir.as_ref().ok_or_else(|| {
                anyhow::anyhow!(
                    "resume requested but no checkpoint directory set \
                     (--ckpt-dir / DYNAMIX_CKPT_DIR)"
                )
            })?;
            let (_, path) = ckpt::latest(dir).ok_or_else(|| {
                anyhow::anyhow!("resume requested but no ckpt-<step>.bin under {dir:?}")
            })?;
            Some(ckpt::load(&path, &self.ckpt_header())?)
        } else {
            None
        };

        let mut detector;
        let mut batch_trace: Vec<(usize, f64, f64)>;
        let mut cycle;
        let mut final_eval;
        let start_step;
        // Scenario events already journaled (resume: everything the image
        // carries was journaled before the crash).
        let mut events_logged;
        if let Some(s) = restored {
            self.trainer.restore(&s.trainer)?;
            self.agent.restore(&s.agent)?;
            self.eval_history = s.eval_history.clone();
            self.calibrated = s.calibrated;
            self.state_builder.iter_time_ref = s.state_iter_time_ref;
            self.reward.iter_time_ref = s.reward_iter_time_ref;
            detector = ConvergenceDetector::from_snapshot(&s.detector);
            *record = s.record.clone();
            batch_trace = record
                .points
                .iter()
                .enumerate()
                .map(|(i, p)| (i, p.batch_mean, p.batch_std))
                .collect();
            cycle = CycleOutcome {
                states: s.cycle.states.iter().cloned().map(StateVector).collect(),
                rewards: s.cycle.rewards.clone(),
                active: s.cycle.active.clone(),
                sim_clock: s.cycle.sim_clock,
                train_acc: s.cycle.train_acc,
                eval_acc: s.cycle.eval_acc,
                loss: s.cycle.loss,
            };
            final_eval = s.cycle.eval_acc;
            start_step = s.step;
            events_logged = self.trainer.events_applied.len();
        } else {
            self.trainer
                .reset_episode(self.cfg.train.seed, self.cfg.batch.initial)?;
            self.eval_history.clear();
            self.calibrated = false;
            detector = ConvergenceDetector::new(self.cfg.train.target_acc, 2);
            batch_trace = Vec::new();
            events_logged = 0;
            cycle = self.run_cycle(0.0)?;
            final_eval = cycle.eval_acc;
            if let Some(j) = &journal {
                for (at, desc) in &self.trainer.events_applied[events_logged..] {
                    j.event(*at, desc)?;
                }
                events_logged = self.trainer.events_applied.len();
            }
        }

        for step in start_step..max_cycles {
            if let Some(dir) = &self.ckpt_dir {
                if step % self.ckpt_every == 0 {
                    let image = self.capture(step, &detector, record, &cycle);
                    ckpt::save_atomic(dir, &self.ckpt_header(), &image)?;
                    // Retention GC strictly after the successful write:
                    // the image just saved is the newest, so it always
                    // survives; prune failures are warnings, never fatal.
                    if let Some(keep) = self.ckpt_keep {
                        ckpt::prune(dir, keep);
                    }
                    if let Some(j) = &journal {
                        j.checkpoint(step, cycle.sim_clock)?;
                    }
                }
            }
            // Trace statistics span the LIVE membership only.
            let (bm, bs) = mean_std_usize(&self.trainer.active_batches());
            batch_trace.push((step, bm, bs));
            record.push(TracePoint {
                iter: self.trainer.iter,
                sim_time: cycle.sim_clock,
                train_acc: cycle.train_acc,
                eval_acc: cycle.eval_acc,
                loss: cycle.loss,
                batch_mean: bm,
                batch_std: bs,
                global_batch: self.trainer.global_batch(),
            });
            detector.observe(cycle.eval_acc, cycle.sim_clock);
            final_eval = cycle.eval_acc;
            if let Some(j) = &journal {
                j.cycle(
                    step,
                    cycle.sim_clock,
                    self.trainer.iter,
                    self.trainer.global_batch(),
                    cycle.eval_acc,
                )?;
            }
            if detector.converged() {
                break;
            }
            let samples = self.agent.act(&cycle.states, false)?;
            self.apply_actions(&samples.iter().map(|s| s.action).collect::<Vec<_>>());
            cycle = self.run_cycle((step + 1) as f64 / max_cycles as f64)?;
            if let Some(j) = &journal {
                for (at, desc) in &self.trainer.events_applied[events_logged..] {
                    j.event(*at, desc)?;
                }
                events_logged = self.trainer.events_applied.len();
            }
        }

        record.final_eval_acc = final_eval;
        record.convergence_time = detector.time();
        self.trainer.annotate_record(record);
        Ok(InferenceSummary {
            final_eval_acc: final_eval,
            best_eval_acc: record.best_eval_acc(),
            convergence_time: detector.time(),
            total_sim_time: self.trainer.cluster.clock,
            total_iters: self.trainer.iter,
            batch_trace,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    fn cfg() -> ExperimentConfig {
        let mut c = ExperimentConfig::default();
        c.cluster.n_workers = 4;
        c.batch.initial = 64;
        c.rl.k = 2;
        c.steps_per_episode = 4;
        c.train.max_steps = 100;
        c.train.eval_every = 2;
        c
    }

    fn backend() -> Backend {
        crate::runtime::native_backend()
    }

    #[test]
    fn train_rl_produces_episode_results() {
        let mut c = Coordinator::new(cfg(), backend()).unwrap();
        let results = c.train_rl(2).unwrap();
        assert_eq!(results.len(), 2);
        for r in &results {
            assert_eq!(r.worker_returns.len(), 4);
            assert!(r.mean_return.is_finite());
            assert!(r.update.minibatches > 0);
            assert!(r.sim_time > 0.0);
            assert!((0.0..=1.0).contains(&r.final_eval_acc));
        }
    }

    #[test]
    fn inference_records_trace_and_respects_constraints() {
        let mut c = Coordinator::new(cfg(), backend()).unwrap();
        let mut record = RunRecord::new("test");
        let summary = c.run_inference(5, &mut record).unwrap();
        assert!(!record.points.is_empty());
        assert!(summary.total_iters > 0);
        assert!(!summary.batch_trace.is_empty());
        for &b in &c.trainer.batches {
            assert!((32..=1024).contains(&b), "batch {b} out of range");
        }
    }

    #[test]
    fn churn_scenario_masks_absent_workers_and_annotates_record() {
        use crate::sim::scenario::{ScenarioEvent, ScenarioScript, TimedEvent};
        let mut c = cfg();
        c.scenario = Some(ScenarioScript {
            name: "churn".into(),
            events: vec![
                TimedEvent {
                    at_s: 0.0,
                    event: ScenarioEvent::PreemptWorker { worker: 2 },
                },
                TimedEvent {
                    at_s: 0.02,
                    event: ScenarioEvent::LoadShift {
                        worker: 0,
                        load_mean: 0.5,
                    },
                },
            ],
        });
        let mut coord = Coordinator::new(c, backend()).unwrap();
        let mut record = RunRecord::new("churn-infer");
        let summary = coord.run_inference(4, &mut record).unwrap();
        assert!(summary.total_iters > 0);
        assert_eq!(coord.trainer.n_active(), 3, "preemption persisted");
        // Global batch spans the 3 live workers only (preempted at t=0,
        // before the first recorded point).
        for p in &record.points {
            assert!(
                (3 * 32..=3 * 1024).contains(&p.global_batch),
                "global batch {} outside 3-worker range",
                p.global_batch
            );
        }
        assert_eq!(
            record.extra.get("scenario").and_then(crate::util::json::Json::as_str),
            Some("churn")
        );
        assert!(record.extra.contains_key("scenario_timeline"));
        for w in 0..4 {
            if coord.trainer.is_active(w) {
                assert!((32..=1024).contains(&coord.trainer.batches[w]));
            }
        }
    }

    #[test]
    fn train_rl_learns_through_preemption() {
        use crate::sim::scenario::{ScenarioEvent, ScenarioScript, TimedEvent};
        let mut c = cfg();
        c.scenario = Some(ScenarioScript {
            name: "mid-episode-churn".into(),
            events: vec![
                TimedEvent {
                    at_s: 0.05,
                    event: ScenarioEvent::PreemptWorker { worker: 3 },
                },
                TimedEvent {
                    at_s: 0.30,
                    event: ScenarioEvent::RejoinWorker { worker: 3 },
                },
            ],
        });
        let mut coord = Coordinator::new(c, backend()).unwrap();
        let results = coord.train_rl(1).unwrap();
        assert_eq!(results.len(), 1);
        assert!(results[0].mean_return.is_finite());
        assert!(results[0].update.minibatches > 0, "masked workers still leave a batch");
    }

    fn assert_records_bitwise_eq(a: &RunRecord, b: &RunRecord) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.points.len(), b.points.len(), "point counts differ");
        for (i, (p, q)) in a.points.iter().zip(&b.points).enumerate() {
            assert_eq!(p.iter, q.iter, "point {i} iter");
            assert_eq!(p.sim_time.to_bits(), q.sim_time.to_bits(), "point {i} sim_time");
            assert_eq!(p.train_acc.to_bits(), q.train_acc.to_bits(), "point {i} train_acc");
            assert_eq!(p.eval_acc.to_bits(), q.eval_acc.to_bits(), "point {i} eval_acc");
            assert_eq!(p.loss.to_bits(), q.loss.to_bits(), "point {i} loss");
            assert_eq!(p.batch_mean.to_bits(), q.batch_mean.to_bits(), "point {i} bm");
            assert_eq!(p.batch_std.to_bits(), q.batch_std.to_bits(), "point {i} bs");
            assert_eq!(p.global_batch, q.global_batch, "point {i} global_batch");
        }
        assert_eq!(a.final_eval_acc.to_bits(), b.final_eval_acc.to_bits());
        assert_eq!(
            a.convergence_time.map(f64::to_bits),
            b.convergence_time.map(f64::to_bits)
        );
        assert_eq!(a.total_sim_time.to_bits(), b.total_sim_time.to_bits());
        assert_eq!(a.total_iters, b.total_iters);
        assert_eq!(a.extra, b.extra, "record extras differ");
    }

    #[test]
    fn checkpointed_inference_resumes_bitwise() {
        use crate::util::json::Json;
        let dir = std::env::temp_dir().join(format!(
            "dynamix_coord_ckpt_{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        // Uninterrupted reference run.
        let mut a = Coordinator::new(cfg(), backend()).unwrap();
        let mut ra = RunRecord::new("durable");
        a.run_inference(6, &mut ra).unwrap();
        // Checkpointed run over the SAME horizon (progress = step /
        // max_cycles feeds the policy state, so a resume must share the
        // original horizon). Simulate a crash after the step-2 image by
        // deleting every later one.
        let mut b = Coordinator::new(cfg(), backend()).unwrap();
        b.set_ckpt_policy(Some(dir.clone()), 2);
        let mut rb = RunRecord::new("durable");
        b.run_inference(6, &mut rb).unwrap();
        while let Some((step, path)) = crate::ckpt::latest(&dir) {
            if step <= 2 {
                break;
            }
            std::fs::remove_file(&path).unwrap();
        }
        let latest = crate::ckpt::latest(&dir).map(|(s, _)| s);
        assert!(latest.map_or(false, |s| s <= 2), "latest image {latest:?}");
        // Resume in a FRESH coordinator and run to the full horizon.
        let mut c = Coordinator::new(cfg(), backend()).unwrap();
        c.set_ckpt_policy(Some(dir.clone()), 2);
        c.set_resume(true);
        let mut rc = RunRecord::new("overwritten-by-restore");
        c.run_inference(6, &mut rc).unwrap();
        assert_records_bitwise_eq(&ra, &rc);
        // The journal saw cycles, checkpoints, and only sim-time stamps.
        let lines = crate::ckpt::Journal::read(&dir).unwrap();
        assert!(lines
            .iter()
            .any(|l| l.get("kind").and_then(Json::as_str) == Some("ckpt")));
        assert!(lines
            .iter()
            .any(|l| l.get("kind").and_then(Json::as_str) == Some("cycle")));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn resume_rejects_cross_plane_checkpoint() {
        let dir = std::env::temp_dir().join(format!(
            "dynamix_coord_xplane_{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        let mut a = Coordinator::new(cfg(), backend()).unwrap();
        a.set_ckpt_policy(Some(dir.clone()), 1);
        let mut ra = RunRecord::new("xplane");
        a.run_inference(2, &mut ra).unwrap();
        // Rewrite the latest image under the other plane's fingerprint.
        let (_, path) = crate::ckpt::latest(&dir).unwrap();
        let mut h = a.ckpt_header();
        let image = crate::ckpt::load(&path, &h).unwrap();
        h.plane = "replica".into();
        crate::ckpt::save_atomic(&dir, &h, &image).unwrap();
        // A zero-plane resume must refuse it, naming both planes.
        let mut b = Coordinator::new(cfg(), backend()).unwrap();
        b.set_ckpt_policy(Some(dir.clone()), 1);
        b.set_resume(true);
        let mut rb = RunRecord::new("xplane");
        let err = b.run_inference(2, &mut rb).unwrap_err().to_string();
        assert!(
            err.contains("DYNAMIX_PLANE") && err.contains("\"replica\"") && err.contains("\"zero\""),
            "{err}"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn episodes_reset_cleanly() {
        let mut c = Coordinator::new(cfg(), backend()).unwrap();
        let r1 = c.train_rl(1).unwrap();
        let r2 = c.train_rl(1).unwrap();
        // Fresh episode each time: sim time restarts rather than
        // accumulating across calls.
        assert!(r2[0].sim_time < r1[0].sim_time * 3.0);
    }

    #[test]
    fn rl_loop_runs_on_the_sharded_data_plane_through_churn() {
        // The full arbitrator cycle — fused steps, masked RL state,
        // policy updates — over the sharded loopback backend, with a
        // scenario that drops and revives a worker/shard mid-episode.
        use crate::runtime::ShardedBackend;
        use crate::sim::scenario::{ScenarioEvent, ScenarioScript, TimedEvent};
        use std::sync::Arc;
        let mut c = cfg();
        c.scenario = Some(ScenarioScript {
            name: "shard-churn".into(),
            events: vec![
                TimedEvent { at_s: 0.05, event: ScenarioEvent::PreemptWorker { worker: 1 } },
                TimedEvent { at_s: 0.30, event: ScenarioEvent::RejoinWorker { worker: 1 } },
            ],
        });
        let sharded: Backend = Arc::new(ShardedBackend::loopback_with_threads(4, 1));
        let mut coord = Coordinator::new(c, sharded.clone()).unwrap();
        let mut record = RunRecord::new("sharded-churn-infer");
        let summary = coord.run_inference(4, &mut record).unwrap();
        assert!(summary.total_iters > 0);
        // The churn arc completed: full membership again, on both planes.
        assert_eq!(coord.trainer.n_active(), 4);
        assert_eq!(sharded.shard_membership(), vec![true; 4]);
        // Record carries both the data-plane and scenario annotations.
        let dp = record.extra.get("data_plane").expect("data_plane annotation");
        assert_eq!(
            dp.get("shard_count").and_then(crate::util::json::Json::as_usize),
            Some(4)
        );
        assert!(record.extra.contains_key("scenario_timeline"));
    }
}
