//! Reinforcement-learning core (paper §IV).
//!
//! * [`state`]      — the multi-dimensional state representation (§IV-B):
//!   per-worker network / system / training-statistics features plus the
//!   BSP-shared global features, normalized into the 16-dim vector the
//!   `policy_forward` artifact was compiled for.
//! * [`action`]     — the discrete action space A = {-100,-25,0,+25,+100}
//!   with [32,1024] clamping (§IV-C).
//! * [`reward`]     — the SGD and adaptive-optimizer reward functions
//!   (§IV-D).
//! * [`trajectory`] — per-worker rollout buffers + GAE.
//! * [`agent`]      — the PPO arbitrator driver: batched policy inference
//!   and minibatched updates through the AOT policy artifacts. Python is
//!   never involved; the policy's parameters live in this process as
//!   literals fed to `policy_forward` / `policy_update`.

pub mod action;
pub mod agent;
pub mod reward;
pub mod state;
pub mod trajectory;
