//! Discrete action space + batch-size clamping (paper §IV-C).
//!
//! A = {-100, -25, 0, +25, +100}: ±100 for rapid early-phase adaptation,
//! ±25 for fine-grained mid-training adjustment. The updated batch size is
//! clamped to [min, max] ([32, 1024] in the paper) and additionally to the
//! worker's memory ceiling (the §IV-C OOM rule).

/// The paper's action deltas, in artifact logit order.
pub const DELTAS: [i32; 5] = [-100, -25, 0, 25, 100];

pub const N_ACTIONS: usize = DELTAS.len();

/// Batch-size manager for one run: applies deltas under constraints.
#[derive(Clone, Copy, Debug)]
pub struct BatchRule {
    pub min: usize,
    pub max: usize,
}

impl Default for BatchRule {
    fn default() -> Self {
        BatchRule { min: 32, max: 1024 }
    }
}

impl BatchRule {
    /// Apply action index `a` to `batch`, honoring [min, max] and an
    /// optional per-worker memory cap.
    pub fn apply(&self, batch: usize, a: usize, mem_cap: Option<usize>) -> usize {
        let delta = DELTAS[a];
        let raw = batch as i64 + delta as i64;
        let hi = match mem_cap {
            Some(c) => self.max.min(c.max(self.min)),
            None => self.max,
        };
        raw.clamp(self.min as i64, hi as i64) as usize
    }

    /// The delta actually realized after clamping (for logging/comm).
    pub fn realized_delta(&self, batch: usize, a: usize, mem_cap: Option<usize>) -> i32 {
        self.apply(batch, a, mem_cap) as i32 - batch as i32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn action_order_matches_artifact_logits() {
        // The policy artifact's 5 logits are in this exact order.
        assert_eq!(DELTAS, [-100, -25, 0, 25, 100]);
    }

    #[test]
    fn apply_respects_bounds() {
        let r = BatchRule::default();
        assert_eq!(r.apply(32, 0, None), 32, "floor");
        assert_eq!(r.apply(1024, 4, None), 1024, "cap");
        assert_eq!(r.apply(128, 1, None), 103);
        assert_eq!(r.apply(128, 3, None), 153);
        assert_eq!(r.apply(128, 2, None), 128, "no-op action");
        assert_eq!(r.apply(100, 0, None), 32, "clamps to floor not below");
    }

    #[test]
    fn memory_cap_binds() {
        let r = BatchRule::default();
        assert_eq!(r.apply(500, 4, Some(512)), 512);
        assert_eq!(r.apply(500, 4, Some(16)), 32, "cap never below min");
    }

    #[test]
    fn realized_delta_reflects_clamp() {
        let r = BatchRule::default();
        assert_eq!(r.realized_delta(128, 3, None), 25);
        assert_eq!(r.realized_delta(1000, 4, None), 24, "clamped at 1024");
        assert_eq!(r.realized_delta(32, 0, None), 0);
    }

    #[test]
    fn every_batch_in_range_stays_in_range() {
        let r = BatchRule::default();
        for b in (32..=1024).step_by(7) {
            for a in 0..N_ACTIONS {
                let nb = r.apply(b, a, None);
                assert!((r.min..=r.max).contains(&nb), "b={b} a={a} -> {nb}");
            }
        }
    }
}
