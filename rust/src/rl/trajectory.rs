//! Rollout storage + Generalized Advantage Estimation.
//!
//! One [`Trajectory`] per worker per episode (the centralized agent
//! produces node-specific actions from shared parameters, §IV-A; the
//! overall objective sums per-node surrogate losses, so the update buffer
//! simply concatenates all workers' transitions).

use super::state::StateVector;

/// One transition of one worker.
#[derive(Clone, Debug)]
pub struct Transition {
    pub state: StateVector,
    pub action: usize,
    pub logp: f32,
    pub value: f32,
    pub reward: f64,
}

/// Per-worker episode rollout.
#[derive(Clone, Debug, Default)]
pub struct Trajectory {
    pub steps: Vec<Transition>,
}

impl Trajectory {
    pub fn push(&mut self, t: Transition) {
        self.steps.push(t);
    }

    pub fn len(&self) -> usize {
        self.steps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }

    pub fn total_reward(&self) -> f64 {
        self.steps.iter().map(|t| t.reward).sum()
    }

    /// GAE(γ, λ) advantages + discounted-return targets.
    ///
    /// Episodes terminate at the buffer end (bootstrap value 0), matching
    /// the episodic protocol of §VI-C where each episode ends after a
    /// fixed step count.
    pub fn gae(&self, gamma: f64, lambda: f64) -> (Vec<f64>, Vec<f64>) {
        let n = self.steps.len();
        let mut adv = vec![0.0; n];
        let mut gae = 0.0;
        for i in (0..n).rev() {
            let next_v = if i + 1 < n {
                self.steps[i + 1].value as f64
            } else {
                0.0
            };
            let delta = self.steps[i].reward + gamma * next_v - self.steps[i].value as f64;
            gae = delta + gamma * lambda * gae;
            adv[i] = gae;
        }
        let ret: Vec<f64> = adv
            .iter()
            .zip(&self.steps)
            .map(|(a, t)| a + t.value as f64)
            .collect();
        (adv, ret)
    }
}

/// Flattened multi-worker update batch with normalized advantages.
#[derive(Debug, Default)]
pub struct UpdateBatch {
    pub states: Vec<StateVector>,
    pub actions: Vec<usize>,
    pub old_logp: Vec<f32>,
    pub advantages: Vec<f32>,
    pub returns: Vec<f32>,
}

impl UpdateBatch {
    /// Build from all workers' trajectories; advantages are normalized to
    /// zero mean / unit std across the whole batch (standard PPO practice;
    /// the paper's simplified variant ignores the advantage column).
    pub fn from_trajectories(trajs: &[Trajectory], gamma: f64, lambda: f64) -> UpdateBatch {
        let mut b = UpdateBatch::default();
        for tr in trajs {
            let (adv, ret) = tr.gae(gamma, lambda);
            for (i, t) in tr.steps.iter().enumerate() {
                b.states.push(t.state.clone());
                b.actions.push(t.action);
                b.old_logp.push(t.logp);
                b.advantages.push(adv[i] as f32);
                b.returns.push(ret[i] as f32);
            }
        }
        // Normalize advantages.
        let n = b.advantages.len();
        if n > 1 {
            let mean: f32 = b.advantages.iter().sum::<f32>() / n as f32;
            let var: f32 =
                b.advantages.iter().map(|a| (a - mean) * (a - mean)).sum::<f32>() / n as f32;
            let std = var.sqrt().max(1e-6);
            for a in &mut b.advantages {
                *a = (*a - mean) / std;
            }
        }
        b
    }

    pub fn len(&self) -> usize {
        self.actions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.actions.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn traj(rewards: &[f64], values: &[f32]) -> Trajectory {
        let mut t = Trajectory::default();
        for (i, (&r, &v)) in rewards.iter().zip(values).enumerate() {
            t.push(Transition {
                state: StateVector(vec![i as f32; 16]),
                action: i % 5,
                logp: -1.6,
                value: v,
                reward: r,
            });
        }
        t
    }

    #[test]
    fn gae_matches_hand_computation() {
        // gamma=1, lambda=1 -> advantage = (sum of future rewards) - V.
        let t = traj(&[1.0, 2.0, 3.0], &[0.5, 0.5, 0.5]);
        let (adv, ret) = t.gae(1.0, 1.0);
        assert!((adv[0] - (6.0 - 0.5)).abs() < 1e-9);
        assert!((adv[2] - (3.0 - 0.5)).abs() < 1e-9);
        for (a, r, tr) in adv.iter().zip(&ret).zip(&t.steps).map(|((a, r), t)| (a, r, t)) {
            assert!((r - (a + tr.value as f64)).abs() < 1e-9);
        }
    }

    #[test]
    fn gae_lambda_zero_is_td_error() {
        let t = traj(&[1.0, 1.0], &[0.3, 0.7]);
        let (adv, _) = t.gae(0.9, 0.0);
        // 1e-6 tolerance: stored values are f32.
        assert!((adv[0] - (1.0 + 0.9 * 0.7 - 0.3)).abs() < 1e-6);
        assert!((adv[1] - (1.0 + 0.0 - 0.7)).abs() < 1e-6);
    }

    #[test]
    fn update_batch_concatenates_and_normalizes() {
        let t1 = traj(&[1.0, 2.0], &[0.0, 0.0]);
        let t2 = traj(&[5.0], &[0.0]);
        let b = UpdateBatch::from_trajectories(&[t1, t2], 0.99, 0.95);
        assert_eq!(b.len(), 3);
        let mean: f32 = b.advantages.iter().sum::<f32>() / 3.0;
        let var: f32 = b.advantages.iter().map(|a| (a - mean).powi(2)).sum::<f32>() / 3.0;
        assert!(mean.abs() < 1e-5);
        assert!((var - 1.0).abs() < 1e-3);
    }

    #[test]
    fn total_reward_sums() {
        let t = traj(&[1.0, -2.0, 0.5], &[0.0; 3]);
        assert!((t.total_reward() + 0.5).abs() < 1e-12);
    }

    #[test]
    fn empty_batch_is_safe() {
        let b = UpdateBatch::from_trajectories(&[], 0.99, 0.95);
        assert!(b.is_empty());
    }
}
