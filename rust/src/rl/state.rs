//! State representation (paper §IV-B).
//!
//! 16 features, fixed normalization (documented per-feature below) so the
//! policy sees inputs in roughly [-3, 3] regardless of cluster size or
//! model. Layout is frozen into the AOT `policy_forward` artifact
//! (manifest `state_dim` = [`STATE_DIM`]); changing it requires
//! `make artifacts`.

use crate::sysmetrics::WindowSummary;

pub const STATE_DIM: usize = 16;

/// Feature indices (kept public for the ablation benches).
pub mod idx {
    pub const THROUGHPUT: usize = 0;      // network: goodput
    pub const RETX: usize = 1;            // network: retransmissions
    pub const CPU_RATIO: usize = 2;       // system: cpu time ratio
    pub const MEM_UTIL: usize = 3;        // system: memory utilization
    pub const ACC_MEAN: usize = 4;        // training: mean batch accuracy
    pub const ACC_STD: usize = 5;         // training: accuracy std
    pub const ACC_GAIN: usize = 6;        // training: sliding-window ΔA
    pub const ITER_TIME: usize = 7;       // training: mean iteration time
    pub const SIGMA_NORM: usize = 8;      // optimizer: sigma_norm
    pub const SIGMA_NORM2: usize = 9;     // optimizer: sigma_norm^2
    pub const LOG_BATCH: usize = 10;      // control: log2 batch size
    pub const PROGRESS: usize = 11;       // control: training progress
    pub const GLOBAL_LOSS: usize = 12;    // global: shared loss level
    pub const GLOBAL_ACC: usize = 13;     // global: eval accuracy
    pub const GLOBAL_TREND: usize = 14;   // global: eval accuracy trend
    pub const SCALE: usize = 15;          // global: cluster size
}

/// A normalized state vector (length [`STATE_DIM`]).
#[derive(Clone, Debug, PartialEq)]
pub struct StateVector(pub Vec<f32>);

/// Global (BSP-shared) training signals (§IV-B "global state").
#[derive(Clone, Copy, Debug, Default)]
pub struct GlobalState {
    pub loss: f64,
    pub eval_acc: f64,
    /// Eval-accuracy delta over the last two evaluations.
    pub eval_trend: f64,
    pub progress: f64,
    pub n_workers: usize,
}

/// Builder carrying the normalization constants + ablation switches.
#[derive(Clone, Debug)]
pub struct StateBuilder {
    pub use_network_features: bool,
    pub use_grad_stats_features: bool,
    /// Reference iteration time for normalization (seconds). Calibrated
    /// once per run from the first window so the feature is ~1 at start.
    pub iter_time_ref: f64,
}

impl Default for StateBuilder {
    fn default() -> Self {
        StateBuilder {
            use_network_features: true,
            use_grad_stats_features: true,
            iter_time_ref: 0.1,
        }
    }
}

fn clamp3(x: f64) -> f32 {
    x.clamp(-3.0, 3.0) as f32
}

impl StateBuilder {
    /// Build one worker's state vector from its window summary, its
    /// current batch size, and the shared global state.
    pub fn build(
        &self,
        w: &WindowSummary,
        batch: usize,
        global: &GlobalState,
    ) -> StateVector {
        let mut s = vec![0.0f32; STATE_DIM];
        if self.use_network_features {
            // 25 Gbps-class NIC -> ~[0,1.2]; log1p retx compresses bursts.
            s[idx::THROUGHPUT] = clamp3(w.throughput_mean / 25.0);
            s[idx::RETX] = clamp3((1.0 + w.retransmissions).ln() / 10.0);
        }
        s[idx::CPU_RATIO] = clamp3(w.cpu_time_ratio / 4.0);
        s[idx::MEM_UTIL] = clamp3(w.mem_util);
        s[idx::ACC_MEAN] = clamp3(w.acc_mean);
        s[idx::ACC_STD] = clamp3(w.acc_std * 5.0);
        s[idx::ACC_GAIN] = clamp3(w.acc_gain / 3.0);
        s[idx::ITER_TIME] = clamp3(w.iter_time_mean / self.iter_time_ref.max(1e-6));
        if self.use_grad_stats_features {
            s[idx::SIGMA_NORM] = clamp3(w.sigma_norm);
            s[idx::SIGMA_NORM2] = clamp3(w.sigma_norm2);
        }
        s[idx::LOG_BATCH] = clamp3((batch.max(1) as f64).log2() / 10.0);
        s[idx::PROGRESS] = clamp3(global.progress);
        s[idx::GLOBAL_LOSS] = clamp3(global.loss / 5.0);
        s[idx::GLOBAL_ACC] = clamp3(global.eval_acc);
        s[idx::GLOBAL_TREND] = clamp3(global.eval_trend * 20.0);
        s[idx::SCALE] = clamp3(global.n_workers as f64 / 32.0);
        StateVector(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn summary() -> WindowSummary {
        WindowSummary {
            acc_mean: 0.6,
            acc_std: 0.05,
            acc_gain: 1.2,
            iter_time_mean: 0.2,
            throughput_mean: 12.0,
            retransmissions: 150.0,
            cpu_time_ratio: 2.5,
            mem_util: 0.4,
            sigma_norm: 0.9,
            sigma_norm2: 0.81,
            loss_mean: 1.8,
            iters: 5,
        }
    }

    fn global() -> GlobalState {
        GlobalState {
            loss: 1.8,
            eval_acc: 0.55,
            eval_trend: 0.01,
            progress: 0.3,
            n_workers: 16,
        }
    }

    #[test]
    fn builds_bounded_vector() {
        let b = StateBuilder::default();
        let s = b.build(&summary(), 256, &global());
        assert_eq!(s.0.len(), STATE_DIM);
        assert!(s.0.iter().all(|v| v.is_finite() && (-3.0..=3.0).contains(v)));
        assert!(s.0[idx::LOG_BATCH] > 0.0);
        assert!((s.0[idx::SCALE] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn network_ablation_zeroes_features() {
        let mut b = StateBuilder::default();
        b.use_network_features = false;
        let s = b.build(&summary(), 256, &global());
        assert_eq!(s.0[idx::THROUGHPUT], 0.0);
        assert_eq!(s.0[idx::RETX], 0.0);
        assert_ne!(s.0[idx::ACC_MEAN], 0.0);
    }

    #[test]
    fn grad_stats_ablation_zeroes_features() {
        let mut b = StateBuilder::default();
        b.use_grad_stats_features = false;
        let s = b.build(&summary(), 256, &global());
        assert_eq!(s.0[idx::SIGMA_NORM], 0.0);
        assert_eq!(s.0[idx::SIGMA_NORM2], 0.0);
    }

    #[test]
    fn batch_size_monotone_in_feature() {
        let b = StateBuilder::default();
        let lo = b.build(&summary(), 32, &global()).0[idx::LOG_BATCH];
        let hi = b.build(&summary(), 1024, &global()).0[idx::LOG_BATCH];
        assert!(hi > lo);
    }

    #[test]
    fn extreme_inputs_clamped() {
        let mut w = summary();
        w.retransmissions = 1e12;
        w.acc_gain = -1e9;
        let b = StateBuilder::default();
        let s = b.build(&w, 1024, &global());
        assert!(s.0.iter().all(|v| (-3.0..=3.0).contains(v)));
        assert_eq!(s.0[idx::ACC_GAIN], -3.0);
    }
}
