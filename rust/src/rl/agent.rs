//! The PPO arbitrator driver (paper §IV-A, Algorithm 1).
//!
//! Holds the policy parameters as literals and drives the two AOT policy
//! artifacts: `policy_forward` (one call scores all <=32 workers per
//! decision cycle) and `policy_update` / `policy_update_simple`
//! (minibatched PPO epochs over the episode buffer). Everything here is
//! Rust + PJRT — Python is compile-time only.

use crate::config::{PpoVariant, RlConfig};
use crate::rl::trajectory::UpdateBatch;
use crate::runtime::{lit_f32, lit_i32, lit_scalar1, ArtifactStore};
use crate::util::rng::Rng;
use std::sync::Arc;
use xla::Literal;

/// One worker's sampled decision.
#[derive(Clone, Copy, Debug)]
pub struct ActionSample {
    pub action: usize,
    pub logp: f32,
    pub value: f32,
}

/// Aggregate statistics of one policy update.
#[derive(Clone, Copy, Debug, Default)]
pub struct UpdateStats {
    pub loss: f32,
    pub pg_loss: f32,
    pub v_loss: f32,
    pub entropy: f32,
    pub approx_kl: f32,
    pub minibatches: usize,
}

/// PPO agent over the AOT policy artifacts.
pub struct PpoAgent {
    store: Arc<ArtifactStore>,
    theta: Literal,
    m: Literal,
    v: Literal,
    step: Literal,
    pub cfg: RlConfig,
    rng: Rng,
    max_workers: usize,
    state_dim: usize,
    n_actions: usize,
    minibatch: usize,
    /// Decision-cycle latency log (seconds) for the §VI-H overhead study.
    pub inference_seconds: Vec<f64>,
}

impl PpoAgent {
    pub fn new(store: Arc<ArtifactStore>, cfg: RlConfig, seed: u64) -> anyhow::Result<Self> {
        let man = &store.manifest;
        let pc = man.policy_param_count;
        let theta = lit_f32(&man.load_init_policy(seed)?, &[pc as i64])?;
        let zeros = vec![0.0f32; pc];
        Ok(PpoAgent {
            theta,
            m: lit_f32(&zeros, &[pc as i64])?,
            v: lit_f32(&zeros, &[pc as i64])?,
            step: lit_scalar1(0.0),
            cfg,
            rng: Rng::new(seed ^ 0xA6E7),
            max_workers: man.max_workers,
            state_dim: man.state_dim,
            n_actions: man.n_actions,
            minibatch: man.ppo_minibatch,
            store,
            inference_seconds: Vec::new(),
        })
    }

    /// Restore policy parameters from a raw f32 snapshot (policy transfer,
    /// §VI-F) and reset optimizer state.
    pub fn load_theta(&mut self, theta: &[f32]) -> anyhow::Result<()> {
        let pc = self.store.manifest.policy_param_count;
        anyhow::ensure!(theta.len() == pc, "theta len {} != {pc}", theta.len());
        self.theta = lit_f32(theta, &[pc as i64])?;
        let zeros = vec![0.0f32; pc];
        self.m = lit_f32(&zeros, &[pc as i64])?;
        self.v = lit_f32(&zeros, &[pc as i64])?;
        self.step = lit_scalar1(0.0);
        Ok(())
    }

    /// Snapshot current policy parameters.
    pub fn theta_snapshot(&self) -> anyhow::Result<Vec<f32>> {
        Ok(self.theta.to_vec::<f32>()?)
    }

    pub fn save_theta(&self, path: &std::path::Path) -> anyhow::Result<()> {
        let theta = self.theta_snapshot()?;
        let bytes: Vec<u8> = theta.iter().flat_map(|f| f.to_le_bytes()).collect();
        std::fs::write(path, bytes)?;
        Ok(())
    }

    pub fn load_theta_file(&mut self, path: &std::path::Path) -> anyhow::Result<()> {
        let bytes = std::fs::read(path)?;
        anyhow::ensure!(bytes.len() % 4 == 0, "{path:?} not f32-aligned");
        let theta: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        self.load_theta(&theta)
    }

    /// Score every worker's state in one `policy_forward` call and sample
    /// (explore=true) or take the argmax (greedy inference, §VI-D).
    pub fn act(
        &mut self,
        states: &[crate::rl::state::StateVector],
        explore: bool,
    ) -> anyhow::Result<Vec<ActionSample>> {
        anyhow::ensure!(
            states.len() <= self.max_workers,
            "{} workers > artifact max {}",
            states.len(),
            self.max_workers
        );
        let t0 = std::time::Instant::now();
        let mut flat = vec![0.0f32; self.max_workers * self.state_dim];
        for (w, s) in states.iter().enumerate() {
            anyhow::ensure!(s.0.len() == self.state_dim, "bad state dim");
            flat[w * self.state_dim..(w + 1) * self.state_dim].copy_from_slice(&s.0);
        }
        let states_lit = lit_f32(&flat, &[self.max_workers as i64, self.state_dim as i64])?;
        let out = self.store.run("policy_forward", &[&self.theta, &states_lit])?;
        let logp = out.vec_f32(0)?;
        let values = out.vec_f32(1)?;

        let mut samples = Vec::with_capacity(states.len());
        for w in 0..states.len() {
            let row = &logp[w * self.n_actions..(w + 1) * self.n_actions];
            let action = if explore {
                let probs: Vec<f64> = row.iter().map(|&l| (l as f64).exp()).collect();
                self.rng.categorical(&probs)
            } else {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap()
            };
            samples.push(ActionSample {
                action,
                logp: row[action],
                value: values[w],
            });
        }
        self.inference_seconds.push(t0.elapsed().as_secs_f64());
        Ok(samples)
    }

    /// Run `cfg.update_epochs` PPO epochs over the batch in shuffled
    /// minibatches of the artifact's compiled size (padded + masked).
    pub fn update(&mut self, batch: &UpdateBatch) -> anyhow::Result<UpdateStats> {
        if batch.is_empty() {
            return Ok(UpdateStats::default());
        }
        let artifact = match self.cfg.variant {
            PpoVariant::Clipped => "policy_update",
            PpoVariant::Simplified => "policy_update_simple",
        };
        let mb = self.minibatch;
        let lr = lit_scalar1(self.cfg.lr);
        let clip = lit_scalar1(self.cfg.clip_eps);
        let ent = lit_scalar1(self.cfg.ent_coef);
        let vf = lit_scalar1(self.cfg.vf_coef);

        let mut stats = UpdateStats::default();
        let mut order: Vec<usize> = (0..batch.len()).collect();
        for _ in 0..self.cfg.update_epochs {
            self.rng.shuffle(&mut order);
            for chunk in order.chunks(mb) {
                let mut states = vec![0.0f32; mb * self.state_dim];
                let mut actions = vec![0i32; mb];
                let mut old_logp = vec![0.0f32; mb];
                let mut adv = vec![0.0f32; mb];
                let mut ret = vec![0.0f32; mb];
                let mut mask = vec![0.0f32; mb];
                for (row, &i) in chunk.iter().enumerate() {
                    states[row * self.state_dim..(row + 1) * self.state_dim]
                        .copy_from_slice(&batch.states[i].0);
                    actions[row] = batch.actions[i] as i32;
                    old_logp[row] = batch.old_logp[i];
                    adv[row] = batch.advantages[i];
                    ret[row] = batch.returns[i];
                    mask[row] = 1.0;
                }
                let states_l = lit_f32(&states, &[mb as i64, self.state_dim as i64])?;
                let actions_l = lit_i32(&actions, &[mb as i64])?;
                let old_l = lit_f32(&old_logp, &[mb as i64])?;
                let adv_l = lit_f32(&adv, &[mb as i64])?;
                let ret_l = lit_f32(&ret, &[mb as i64])?;
                let mask_l = lit_f32(&mask, &[mb as i64])?;
                let mut out = self.store.run(
                    artifact,
                    &[
                        &self.theta, &self.m, &self.v, &self.step, &states_l, &actions_l,
                        &old_l, &adv_l, &ret_l, &mask_l, &lr, &clip, &ent, &vf,
                    ],
                )?;
                stats.loss = out.scalar_f32(4)?;
                stats.pg_loss = out.scalar_f32(5)?;
                stats.v_loss = out.scalar_f32(6)?;
                stats.entropy = out.scalar_f32(7)?;
                stats.approx_kl = out.scalar_f32(8)?;
                stats.minibatches += 1;
                self.theta = out.take(0);
                self.m = out.take(1);
                self.v = out.take(2);
                self.step = out.take(3);
            }
        }
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rl::state::{StateVector, STATE_DIM};
    use crate::rl::trajectory::{Trajectory, Transition};

    fn agent(variant: PpoVariant) -> PpoAgent {
        let store = Arc::new(ArtifactStore::open_default().unwrap());
        let mut cfg = RlConfig::default();
        cfg.variant = variant;
        cfg.update_epochs = 2;
        // Test-sized learning rate: few minibatches, strong signal.
        cfg.lr = 5e-3;
        PpoAgent::new(store, cfg, 0).unwrap()
    }

    fn state(fill: f32) -> StateVector {
        StateVector(vec![fill; STATE_DIM])
    }

    #[test]
    fn act_returns_valid_samples_and_logs_latency() {
        let mut a = agent(PpoVariant::Clipped);
        let states: Vec<_> = (0..8).map(|i| state(i as f32 * 0.1)).collect();
        let out = a.act(&states, true).unwrap();
        assert_eq!(out.len(), 8);
        for s in &out {
            assert!(s.action < 5);
            assert!(s.logp <= 0.0);
            assert!(s.value.is_finite());
        }
        assert_eq!(a.inference_seconds.len(), 1);
        // Greedy is deterministic.
        let g1 = a.act(&states, false).unwrap();
        let g2 = a.act(&states, false).unwrap();
        assert_eq!(
            g1.iter().map(|s| s.action).collect::<Vec<_>>(),
            g2.iter().map(|s| s.action).collect::<Vec<_>>()
        );
    }

    #[test]
    fn update_moves_policy_toward_rewarded_action() {
        let mut a = agent(PpoVariant::Clipped);
        let probe = vec![state(0.2)];
        // Build a trajectory that always rewards action 4 (+100).
        for _ in 0..12 {
            let mut tr = Trajectory::default();
            for _ in 0..32 {
                let s = state(0.2);
                let sample = a.act(&[s.clone()], true).unwrap()[0];
                let reward = if sample.action == 4 { 2.0 } else { -1.0 };
                tr.push(Transition {
                    state: s,
                    action: sample.action,
                    logp: sample.logp,
                    value: sample.value,
                    reward,
                });
            }
            let batch = UpdateBatch::from_trajectories(&[tr], 0.99, 0.95);
            let stats = a.update(&batch).unwrap();
            assert!(stats.minibatches > 0);
            assert!(stats.loss.is_finite());
        }
        let probs = a.act(&probe, true).unwrap();
        // After training, greedy action should be 4 with high probability.
        let greedy = a.act(&probe, false).unwrap()[0];
        assert_eq!(greedy.action, 4, "policy failed to learn (logp {probs:?})");
    }

    #[test]
    fn simplified_variant_also_updates() {
        let mut a = agent(PpoVariant::Simplified);
        let mut tr = Trajectory::default();
        for _ in 0..16 {
            let s = state(0.1);
            let sample = a.act(&[s.clone()], true).unwrap()[0];
            tr.push(Transition {
                state: s,
                action: sample.action,
                logp: sample.logp,
                value: sample.value,
                reward: 1.0,
            });
        }
        let t0 = a.theta_snapshot().unwrap();
        let batch = UpdateBatch::from_trajectories(&[tr], 0.99, 0.95);
        a.update(&batch).unwrap();
        let t1 = a.theta_snapshot().unwrap();
        assert_ne!(t0, t1);
    }

    #[test]
    fn theta_roundtrip_via_file() {
        let a = agent(PpoVariant::Clipped);
        let path = std::env::temp_dir().join("dynamix_theta_test.f32");
        a.save_theta(&path).unwrap();
        let mut b = agent(PpoVariant::Clipped);
        b.load_theta_file(&path).unwrap();
        assert_eq!(a.theta_snapshot().unwrap(), b.theta_snapshot().unwrap());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn act_rejects_too_many_workers() {
        let mut a = agent(PpoVariant::Clipped);
        let states: Vec<_> = (0..33).map(|_| state(0.0)).collect();
        assert!(a.act(&states, true).is_err());
    }
}
