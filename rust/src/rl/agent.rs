//! The PPO arbitrator driver (paper §IV-A, Algorithm 1).
//!
//! Holds the flat policy parameters + Adam state and drives the backend's
//! two policy entry points: `policy_forward` (one call scores all <=32
//! workers per decision cycle) and `policy_update` /
//! `policy_update_simple` (minibatched PPO epochs over the episode
//! buffer). Backend-agnostic: the same driver runs on the native pure-Rust
//! kernels or the AOT PJRT artifacts.

use crate::config::{PpoVariant, RlConfig};
use crate::rl::trajectory::UpdateBatch;
use crate::runtime::{Backend, OptState, PpoHyper, PpoMinibatch};
use crate::util::rng::Rng;

/// One worker's sampled decision.
#[derive(Clone, Copy, Debug)]
pub struct ActionSample {
    pub action: usize,
    pub logp: f32,
    pub value: f32,
}

/// Aggregate statistics of one policy update: MEANS over every minibatch
/// step of the update (not the last minibatch — see `update`).
#[derive(Clone, Copy, Debug, Default)]
pub struct UpdateStats {
    pub loss: f32,
    pub pg_loss: f32,
    pub v_loss: f32,
    pub entropy: f32,
    pub approx_kl: f32,
    pub minibatches: usize,
}

/// PPO agent over a compute backend's policy kernels.
pub struct PpoAgent {
    backend: Backend,
    opt: OptState,
    pub cfg: RlConfig,
    rng: Rng,
    max_workers: usize,
    state_dim: usize,
    n_actions: usize,
    minibatch: usize,
    /// Decision-cycle latency log (seconds) for the §VI-H overhead study.
    pub inference_seconds: Vec<f64>,
}

impl PpoAgent {
    pub fn new(backend: Backend, cfg: RlConfig, seed: u64) -> anyhow::Result<Self> {
        let s = backend.schema();
        let (max_workers, state_dim, n_actions, minibatch) =
            (s.max_workers, s.state_dim, s.n_actions, s.ppo_minibatch);
        let theta = backend.init_policy(seed)?;
        Ok(PpoAgent {
            opt: OptState::adam(theta),
            cfg,
            rng: Rng::new(seed ^ 0xA6E7),
            max_workers,
            state_dim,
            n_actions,
            minibatch,
            backend,
            inference_seconds: Vec::new(),
        })
    }

    /// Restore policy parameters from a raw f32 snapshot (policy transfer,
    /// §VI-F) and reset optimizer state.
    pub fn load_theta(&mut self, theta: &[f32]) -> anyhow::Result<()> {
        let pc = self.backend.schema().policy_param_count;
        anyhow::ensure!(theta.len() == pc, "theta len {} != {pc}", theta.len());
        self.opt = OptState::adam(theta.to_vec());
        Ok(())
    }

    /// Snapshot current policy parameters.
    pub fn theta_snapshot(&self) -> anyhow::Result<Vec<f32>> {
        Ok(self.opt.params.clone())
    }

    /// Full checkpoint image: params + Adam moments + exploration RNG.
    /// Unlike [`PpoAgent::load_theta`] (policy transfer, which resets the
    /// optimizer), restoring this resumes training bit-for-bit.
    pub fn snapshot(&self) -> AgentState {
        AgentState {
            opt: self.opt.clone(),
            rng: self.rng.state(),
        }
    }

    /// Overwrite optimizer + RNG from an [`AgentState`].
    pub fn restore(&mut self, s: &AgentState) -> anyhow::Result<()> {
        let pc = self.backend.schema().policy_param_count;
        anyhow::ensure!(
            s.opt.params.len() == pc,
            "agent snapshot has {} params, backend expects {pc}",
            s.opt.params.len()
        );
        self.opt = s.opt.clone();
        self.rng = Rng::from_state(s.rng);
        Ok(())
    }

    pub fn save_theta(&self, path: &std::path::Path) -> anyhow::Result<()> {
        let theta = self.theta_snapshot()?;
        let bytes: Vec<u8> = theta.iter().flat_map(|f| f.to_le_bytes()).collect();
        std::fs::write(path, bytes)?;
        Ok(())
    }

    pub fn load_theta_file(&mut self, path: &std::path::Path) -> anyhow::Result<()> {
        let bytes = std::fs::read(path)?;
        anyhow::ensure!(bytes.len() % 4 == 0, "{path:?} not f32-aligned");
        let theta: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        self.load_theta(&theta)
    }

    /// Score every worker's state in one `policy_forward` call and sample
    /// (explore=true) or take the argmax (greedy inference, §VI-D).
    pub fn act(
        &mut self,
        states: &[crate::rl::state::StateVector],
        explore: bool,
    ) -> anyhow::Result<Vec<ActionSample>> {
        anyhow::ensure!(
            states.len() <= self.max_workers,
            "{} workers > backend max {}",
            states.len(),
            self.max_workers
        );
        let t0 = std::time::Instant::now();
        let mut flat = vec![0.0f32; self.max_workers * self.state_dim];
        for (w, s) in states.iter().enumerate() {
            anyhow::ensure!(s.0.len() == self.state_dim, "bad state dim");
            flat[w * self.state_dim..(w + 1) * self.state_dim].copy_from_slice(&s.0);
        }
        let out = self.backend.policy_forward(&self.opt.params, &flat)?;
        let (logp, values) = (out.logp, out.values);

        let mut samples = Vec::with_capacity(states.len());
        for w in 0..states.len() {
            let row = &logp[w * self.n_actions..(w + 1) * self.n_actions];
            let action = if explore {
                let probs: Vec<f64> = row.iter().map(|&l| (l as f64).exp()).collect();
                self.rng.categorical(&probs)
            } else {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap()
            };
            samples.push(ActionSample {
                action,
                logp: row[action],
                value: values[w],
            });
        }
        self.inference_seconds.push(t0.elapsed().as_secs_f64());
        Ok(samples)
    }

    /// Run `cfg.update_epochs` PPO epochs over the batch in shuffled
    /// minibatches of the backend's compiled size (padded + masked).
    /// Reported stats are MEANS across every minibatch step, so Fig. 3
    /// reward curves and the overhead study see the whole update, not
    /// whichever minibatch happened to run last.
    pub fn update(&mut self, batch: &UpdateBatch) -> anyhow::Result<UpdateStats> {
        if batch.is_empty() {
            return Ok(UpdateStats::default());
        }
        let mb = self.minibatch;
        let hp = PpoHyper {
            lr: self.cfg.lr,
            clip_eps: self.cfg.clip_eps,
            ent_coef: self.cfg.ent_coef,
            vf_coef: self.cfg.vf_coef,
        };

        let mut sums = [0.0f64; 5]; // loss, pg, v, entropy, kl
        let mut count = 0usize;
        let mut order: Vec<usize> = (0..batch.len()).collect();
        let mut states = vec![0.0f32; mb * self.state_dim];
        let mut actions = vec![0i32; mb];
        let mut old_logp = vec![0.0f32; mb];
        let mut adv = vec![0.0f32; mb];
        let mut ret = vec![0.0f32; mb];
        let mut mask = vec![0.0f32; mb];
        for _ in 0..self.cfg.update_epochs {
            self.rng.shuffle(&mut order);
            for chunk in order.chunks(mb) {
                states.iter_mut().for_each(|v| *v = 0.0);
                mask.iter_mut().for_each(|v| *v = 0.0);
                actions.iter_mut().for_each(|v| *v = 0);
                old_logp.iter_mut().for_each(|v| *v = 0.0);
                adv.iter_mut().for_each(|v| *v = 0.0);
                ret.iter_mut().for_each(|v| *v = 0.0);
                for (row, &i) in chunk.iter().enumerate() {
                    states[row * self.state_dim..(row + 1) * self.state_dim]
                        .copy_from_slice(&batch.states[i].0);
                    actions[row] = batch.actions[i] as i32;
                    old_logp[row] = batch.old_logp[i];
                    adv[row] = batch.advantages[i];
                    ret[row] = batch.returns[i];
                    mask[row] = 1.0;
                }
                let minibatch = PpoMinibatch {
                    states: &states,
                    actions: &actions,
                    old_logp: &old_logp,
                    advantages: &adv,
                    returns: &ret,
                    mask: &mask,
                };
                let s =
                    self.backend
                        .policy_update(self.cfg.variant, &mut self.opt, &minibatch, hp)?;
                sums[0] += s.loss as f64;
                sums[1] += s.pg_loss as f64;
                sums[2] += s.v_loss as f64;
                sums[3] += s.entropy as f64;
                sums[4] += s.approx_kl as f64;
                count += 1;
            }
        }
        let n = count.max(1) as f64;
        Ok(UpdateStats {
            loss: (sums[0] / n) as f32,
            pg_loss: (sums[1] / n) as f32,
            v_loss: (sums[2] / n) as f32,
            entropy: (sums[3] / n) as f32,
            approx_kl: (sums[4] / n) as f32,
            minibatches: count,
        })
    }
}

/// Serializable checkpoint image of a [`PpoAgent`]'s mutable state.
#[derive(Clone, Debug)]
pub struct AgentState {
    /// Policy parameters + Adam moments + step counter.
    pub opt: OptState,
    /// Exploration/minibatch-shuffle RNG stream.
    pub rng: [u64; 4],
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rl::state::{StateVector, STATE_DIM};
    use crate::rl::trajectory::{Trajectory, Transition};
    use crate::runtime::native_backend;

    fn agent(variant: PpoVariant) -> PpoAgent {
        let mut cfg = RlConfig::default();
        cfg.variant = variant;
        cfg.update_epochs = 2;
        // Test-sized learning rate: few minibatches, strong signal.
        cfg.lr = 5e-3;
        PpoAgent::new(native_backend(), cfg, 0).unwrap()
    }

    fn state(fill: f32) -> StateVector {
        StateVector(vec![fill; STATE_DIM])
    }

    #[test]
    fn act_returns_valid_samples_and_logs_latency() {
        let mut a = agent(PpoVariant::Clipped);
        let states: Vec<_> = (0..8).map(|i| state(i as f32 * 0.1)).collect();
        let out = a.act(&states, true).unwrap();
        assert_eq!(out.len(), 8);
        for s in &out {
            assert!(s.action < 5);
            assert!(s.logp <= 0.0);
            assert!(s.value.is_finite());
        }
        assert_eq!(a.inference_seconds.len(), 1);
        // Greedy is deterministic.
        let g1 = a.act(&states, false).unwrap();
        let g2 = a.act(&states, false).unwrap();
        assert_eq!(
            g1.iter().map(|s| s.action).collect::<Vec<_>>(),
            g2.iter().map(|s| s.action).collect::<Vec<_>>()
        );
    }

    #[test]
    fn update_moves_policy_toward_rewarded_action() {
        let mut a = agent(PpoVariant::Clipped);
        let probe = vec![state(0.2)];
        // Bandit-style trajectory that always rewards action 4. gamma = 0
        // gives exact per-step credit assignment (each advantage reflects
        // only its own action's reward), so 12 rounds converge decisively.
        for _ in 0..12 {
            let mut tr = Trajectory::default();
            for _ in 0..32 {
                let s = state(0.2);
                let sample = a.act(&[s.clone()], true).unwrap()[0];
                let reward = if sample.action == 4 { 2.0 } else { -1.0 };
                tr.push(Transition {
                    state: s,
                    action: sample.action,
                    logp: sample.logp,
                    value: sample.value,
                    reward,
                });
            }
            let batch = UpdateBatch::from_trajectories(&[tr], 0.0, 0.95);
            let stats = a.update(&batch).unwrap();
            assert!(stats.minibatches > 0);
            assert!(stats.loss.is_finite());
        }
        let probs = a.act(&probe, true).unwrap();
        // After training, greedy action should be 4 with high probability.
        let greedy = a.act(&probe, false).unwrap()[0];
        assert_eq!(greedy.action, 4, "policy failed to learn (logp {probs:?})");
    }

    #[test]
    fn update_stats_are_means_not_last_minibatch() {
        // 600 transitions at minibatch 256 -> 3 minibatches per epoch, 2
        // epochs = 6 steps; `minibatches` must count all of them and the
        // entropy mean must stay in the per-minibatch range (0, ln 5].
        let mut a = agent(PpoVariant::Clipped);
        let mut tr = Trajectory::default();
        for i in 0..600 {
            let s = state((i % 7) as f32 * 0.1);
            let sample = a.act(&[s.clone()], true).unwrap()[0];
            tr.push(Transition {
                state: s,
                action: sample.action,
                logp: sample.logp,
                value: sample.value,
                reward: if sample.action % 2 == 0 { 1.0 } else { -1.0 },
            });
        }
        let batch = UpdateBatch::from_trajectories(&[tr], 0.99, 0.95);
        let stats = a.update(&batch).unwrap();
        assert_eq!(stats.minibatches, 6);
        assert!(stats.entropy > 0.0 && stats.entropy <= (5.0f32).ln() + 1e-3);
        assert!(stats.loss.is_finite() && stats.approx_kl.is_finite());
    }

    #[test]
    fn simplified_variant_also_updates() {
        let mut a = agent(PpoVariant::Simplified);
        let mut tr = Trajectory::default();
        for _ in 0..16 {
            let s = state(0.1);
            let sample = a.act(&[s.clone()], true).unwrap()[0];
            tr.push(Transition {
                state: s,
                action: sample.action,
                logp: sample.logp,
                value: sample.value,
                reward: 1.0,
            });
        }
        let t0 = a.theta_snapshot().unwrap();
        let batch = UpdateBatch::from_trajectories(&[tr], 0.99, 0.95);
        a.update(&batch).unwrap();
        let t1 = a.theta_snapshot().unwrap();
        assert_ne!(t0, t1);
    }

    #[test]
    fn theta_roundtrip_via_file() {
        let a = agent(PpoVariant::Clipped);
        let path = std::env::temp_dir().join("dynamix_theta_test.f32");
        a.save_theta(&path).unwrap();
        let mut b = agent(PpoVariant::Clipped);
        b.load_theta_file(&path).unwrap();
        assert_eq!(a.theta_snapshot().unwrap(), b.theta_snapshot().unwrap());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn agent_snapshot_resumes_training_bitwise() {
        let mk_batch = |a: &mut PpoAgent| {
            let mut tr = Trajectory::default();
            for i in 0..48 {
                let s = state((i % 5) as f32 * 0.1);
                let sample = a.act(&[s.clone()], true).unwrap()[0];
                tr.push(Transition {
                    state: s,
                    action: sample.action,
                    logp: sample.logp,
                    value: sample.value,
                    reward: if sample.action == 2 { 1.0 } else { 0.0 },
                });
            }
            UpdateBatch::from_trajectories(&[tr], 0.99, 0.95)
        };
        let mut a = agent(PpoVariant::Clipped);
        let b0 = mk_batch(&mut a);
        a.update(&b0).unwrap();
        let snap = a.snapshot();
        let ba = mk_batch(&mut a);
        a.update(&ba).unwrap();
        // Restore onto a differently-seeded agent; replay the same steps.
        let mut cfg = RlConfig::default();
        cfg.update_epochs = 2;
        cfg.lr = 5e-3;
        let mut b = PpoAgent::new(native_backend(), cfg, 99).unwrap();
        b.restore(&snap).unwrap();
        let bb = mk_batch(&mut b);
        assert_eq!(
            ba.actions, bb.actions,
            "exploration draws must replay identically"
        );
        b.update(&bb).unwrap();
        let ta: Vec<u32> = a.theta_snapshot().unwrap().iter().map(|f| f.to_bits()).collect();
        let tb: Vec<u32> = b.theta_snapshot().unwrap().iter().map(|f| f.to_bits()).collect();
        assert_eq!(ta, tb);
    }

    #[test]
    fn act_rejects_too_many_workers() {
        let mut a = agent(PpoVariant::Clipped);
        let states: Vec<_> = (0..33).map(|_| state(0.0)).collect();
        assert!(a.act(&states, true).is_err());
    }
}
