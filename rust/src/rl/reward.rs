//! Reward functions (paper §IV-D).
//!
//! SGD regime:
//!   r = Ā + α·max(0, ΔA) − β·T_iter − δ·(log2(B) − 5)
//! Adaptive-optimizer regime adds the gradient-normalization stability
//! penalty:
//!   r -= η·(σ²_norm + σ_norm)
//!
//! T_iter is normalized by a per-run reference time so β has consistent
//! meaning across models/clusters (the paper trains one agent per
//! configuration, which implicitly does the same).

use crate::sysmetrics::WindowSummary;

/// Reward coefficients + regime switch.
#[derive(Clone, Copy, Debug)]
pub struct RewardParams {
    pub alpha: f64,
    pub beta: f64,
    pub delta: f64,
    pub eta: f64,
    /// Apply the η penalty (adaptive optimizers, §IV-D).
    pub adaptive: bool,
    /// Reference iteration time for T_iter normalization (seconds).
    pub iter_time_ref: f64,
}

impl Default for RewardParams {
    fn default() -> Self {
        RewardParams {
            alpha: 2.0,
            beta: 0.5,
            delta: 0.05,
            eta: 0.1,
            adaptive: false,
            iter_time_ref: 0.1,
        }
    }
}

impl RewardParams {
    /// Compute the reward for one worker's k-iteration window (§IV-D).
    pub fn compute(&self, w: &WindowSummary, batch: usize) -> f64 {
        let t_norm = w.iter_time_mean / self.iter_time_ref.max(1e-9);
        let mut r = w.acc_mean + self.alpha * w.acc_gain.max(0.0)
            - self.beta * t_norm
            - self.delta * ((batch.max(1) as f64).log2() - 5.0);
        if self.adaptive {
            r -= self.eta * (w.sigma_norm2 + w.sigma_norm);
        }
        r
    }
}

/// Discounted return of a reward sequence: G_t = Σ γ^i r_{t+i}.
pub fn discounted_returns(rewards: &[f64], gamma: f64) -> Vec<f64> {
    let mut out = vec![0.0; rewards.len()];
    let mut acc = 0.0;
    for i in (0..rewards.len()).rev() {
        acc = rewards[i] + gamma * acc;
        out[i] = acc;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn window(acc: f64, gain: f64, t: f64, sn: f64) -> WindowSummary {
        WindowSummary {
            acc_mean: acc,
            acc_gain: gain,
            iter_time_mean: t,
            sigma_norm: sn,
            sigma_norm2: sn * sn,
            ..Default::default()
        }
    }

    #[test]
    fn baseline_value_matches_formula() {
        let p = RewardParams::default();
        // acc .5, gain 1.0, t = ref, batch 32 (log2-5 = 0)
        let r = p.compute(&window(0.5, 1.0, 0.1, 0.0), 32);
        assert!((r - (0.5 + 2.0 * 1.0 - 0.5 * 1.0)).abs() < 1e-12);
    }

    #[test]
    fn negative_gain_is_neutral() {
        let p = RewardParams::default();
        let r0 = p.compute(&window(0.5, 0.0, 0.1, 0.0), 32);
        let rneg = p.compute(&window(0.5, -2.0, 0.1, 0.0), 32);
        assert_eq!(r0, rneg, "max(0, ΔA) must ignore drops");
    }

    #[test]
    fn slower_iterations_penalized() {
        let p = RewardParams::default();
        let fast = p.compute(&window(0.5, 0.0, 0.05, 0.0), 128);
        let slow = p.compute(&window(0.5, 0.0, 0.5, 0.0), 128);
        assert!(fast > slow);
    }

    #[test]
    fn log_batch_regularizer_centered_at_32() {
        let p = RewardParams::default();
        let at32 = p.compute(&window(0.5, 0.0, 0.1, 0.0), 32);
        let at1024 = p.compute(&window(0.5, 0.0, 0.1, 0.0), 1024);
        // log2(1024)-5 = 5 -> penalty δ*5
        assert!((at32 - at1024 - 0.05 * 5.0).abs() < 1e-12);
    }

    #[test]
    fn eta_penalty_only_when_adaptive() {
        let mut p = RewardParams::default();
        let w = window(0.5, 0.0, 0.1, 0.8);
        let r_sgd = p.compute(&w, 32);
        p.adaptive = true;
        let r_adam = p.compute(&w, 32);
        assert!((r_sgd - r_adam - 0.1 * (0.64 + 0.8)).abs() < 1e-12);
    }

    #[test]
    fn discounted_returns_basic() {
        let g = discounted_returns(&[1.0, 1.0, 1.0], 0.5);
        assert!((g[2] - 1.0).abs() < 1e-12);
        assert!((g[1] - 1.5).abs() < 1e-12);
        assert!((g[0] - 1.75).abs() < 1e-12);
        assert!(discounted_returns(&[], 0.9).is_empty());
    }
}
