//! Gradient-synchronization network simulator.
//!
//! Models the two topologies the paper evaluates (§VI): decentralized
//! **Ring All-Reduce** (primary + OSC testbeds) and a BytePS-style
//! **parameter server** (§VI-G), with an alpha-beta collective cost model
//! plus a congestion/retransmission process. This produces the
//! network-level RL state features (throughput, retransmissions) whose
//! coupling to batch size — larger batches → fewer syncs → less exposure
//! to congestion — is the signal the paper's state design exploits (§IV-B).
//!
//! Cost model (alpha = latency term, beta = byte term):
//!   ring:  t = 2(N-1)·alpha + 2·(N-1)/N · bytes / min_bw
//!   ps:    t = 2·alpha + 2 · bytes · (N/servers) / bw   (incast at servers)
//! Congestion multiplies the effective bandwidth by (1 - c); cross-traffic
//! follows an OU process shared across links (a congested fabric slows
//! everyone, which is what the retransmission counters observe).

//! The congestion level is a [`sim::process::OuProcess`](crate::sim::process::OuProcess)
//! with its own RNG stream, and the fabric supports scenario-driven
//! **congestion storms** ([`NetworkSim::storm`] / [`NetworkSim::relax`]):
//! a storm jumps the level and the OU mean; relax restores the baseline
//! mean and the level decays back through the dynamics.

use crate::cluster::WorkerProfile;
use crate::config::Topology;
use crate::sim::process::{DynamicsProcess, OuProcess, ProcessState};
use crate::util::rng::Rng;

/// Result of simulating one synchronization round.
#[derive(Clone, Copy, Debug)]
pub struct SyncOutcome {
    /// Wall time of the collective in seconds.
    pub time_s: f64,
    /// Total TCP retransmissions observed across the round.
    pub retransmissions: u64,
    /// Achieved goodput in Gbit/s (bytes moved / time).
    pub throughput_gbps: f64,
    /// Congestion level in [0,1) during the round.
    pub congestion: f64,
}

/// Network fabric simulator with a shared congestion process.
pub struct NetworkSim {
    /// Retransmission-count draws (separate stream from the OU diffusion
    /// so scenario events never perturb unrelated randomness).
    rng: Rng,
    /// Shared OU congestion level in [0, 0.9].
    congestion: OuProcess,
    /// Baseline congestion mean (what [`NetworkSim::relax`] restores).
    base_mean: f64,
    /// Construction flavour, so `reset` rebuilds the same fabric.
    noisy: bool,
    /// Retransmissions per (GiB moved × unit congestion).
    pub retx_per_gib: f64,
}

impl NetworkSim {
    fn build(seed: u64, mean: f64, vol: f64, retx_per_gib: f64, noisy: bool) -> Self {
        let root = Rng::new(seed ^ 0x4E75);
        NetworkSim {
            rng: root.split(1),
            congestion: OuProcess::new(mean, 0.3, vol, 0.0, 0.9, root.split(2)),
            base_mean: mean,
            noisy,
            retx_per_gib,
        }
    }

    pub fn new(seed: u64) -> Self {
        Self::build(seed, 0.05, 0.04, 900.0, false)
    }

    /// A noisier fabric (FABRIC testbed / §VI-G heterogeneous cluster).
    pub fn noisy(seed: u64) -> Self {
        Self::build(seed, 0.15, 0.08, 2_500.0, true)
    }

    /// Advance the shared congestion process by `dt` seconds.
    pub fn advance(&mut self, dt: f64) {
        self.congestion.advance(dt);
    }

    pub fn congestion(&self) -> f64 {
        self.congestion.value()
    }

    pub fn congestion_mean(&self) -> f64 {
        self.congestion.mean()
    }

    /// Force the congestion level (tests / deterministic comparisons).
    pub fn set_congestion(&mut self, level: f64) {
        self.congestion.set_level(level);
    }

    /// Pin the OU diffusion volatility (0 makes the fabric deterministic).
    pub fn set_congestion_vol(&mut self, vol: f64) {
        self.congestion.vol = vol;
    }

    /// Shift the long-run congestion mean.
    pub fn set_congestion_mean(&mut self, mean: f64) {
        self.congestion.set_mean(mean);
    }

    /// Scenario event: a cross-traffic storm jumps the congestion level
    /// AND its mean to `level`, so it persists until [`NetworkSim::relax`].
    pub fn storm(&mut self, level: f64) {
        self.congestion.set_level(level);
        self.congestion.set_mean(level);
    }

    /// End a storm: restore the baseline mean; the level decays back
    /// through the OU dynamics rather than snapping.
    pub fn relax(&mut self) {
        self.congestion.set_mean(self.base_mean);
    }

    /// Simulate one gradient synchronization of `grad_bytes` per worker.
    pub fn sync(
        &mut self,
        topology: Topology,
        profiles: &[WorkerProfile],
        grad_bytes: usize,
    ) -> SyncOutcome {
        let n = profiles.len();
        let congestion = self.congestion.value();
        if n <= 1 {
            return SyncOutcome {
                time_s: 0.0,
                retransmissions: 0,
                throughput_gbps: 0.0,
                congestion,
            };
        }
        // The slowest NIC and the largest latency bound the collective.
        let min_bw_gbps = profiles
            .iter()
            .map(|p| p.bandwidth_gbps)
            .fold(f64::INFINITY, f64::min);
        let max_lat_s = profiles
            .iter()
            .map(|p| p.latency_ms / 1e3)
            .fold(0.0f64, f64::max);
        let eff_bw_bytes = min_bw_gbps * (1.0 - congestion) * 1e9 / 8.0;

        let (alpha_terms, bytes_on_wire) = match topology {
            Topology::RingAllReduce => {
                // reduce-scatter + all-gather: 2(N-1) hops of bytes/N.
                let hops = 2.0 * (n as f64 - 1.0);
                (hops * max_lat_s, hops / n as f64 * grad_bytes as f64)
            }
            Topology::ParameterServer { servers } => {
                let s = servers.max(1) as f64;
                // push + pull; server NICs shared by N/s workers (incast).
                (2.0 * max_lat_s, 2.0 * grad_bytes as f64 * (n as f64 / s))
            }
        };
        let transfer_s = bytes_on_wire / eff_bw_bytes;
        let time_s = alpha_terms + transfer_s;

        // Retransmissions scale with bytes moved and congestion.
        let gib = bytes_on_wire * n as f64 / (1024.0 * 1024.0 * 1024.0);
        let lambda = self.retx_per_gib * gib * congestion;
        let retransmissions = self.rng.poisson(lambda);
        // Retransmitted segments add tail latency (~1.5 KB each + RTO slop).
        let retx_penalty = retransmissions as f64 * 1_500.0 / eff_bw_bytes * 4.0;
        let time_s = time_s + retx_penalty;

        SyncOutcome {
            time_s,
            retransmissions,
            throughput_gbps: if time_s > 0.0 {
                bytes_on_wire * 8.0 / 1e9 / time_s
            } else {
                0.0
            },
            congestion,
        }
    }

    /// Simulate one synchronization whose communication is **pipelined
    /// against the backward pass** (the `DYNAMIX_OVERLAP` data plane):
    /// the gradient leaves in `n_buckets` completion-ordered buckets,
    /// bucket `k` becoming sendable once fraction `(k+1)/n_buckets` of
    /// the `compute_s`-second backward has run, and each link carries one
    /// bucket at a time (per-hop serialization — a bucket's transfer
    /// starts at `max(ready_k, link free)`).
    ///
    /// Returns the **exposed** communication time: timeline end minus
    /// `compute_s`, i.e. what the step pays beyond the backward itself —
    /// directly comparable to [`NetworkSim::sync`]'s fully-serialized
    /// `time_s` (that is overlap-off). Every bucket pays the collective's
    /// full alpha (latency) term, so overlap trades `n_buckets - 1` extra
    /// latency rounds for hiding the byte term under compute: it wins
    /// when transfer dominates (constrained bandwidth, big gradients) and
    /// can lose on latency-bound fabrics — the bandwidth-sweep bench
    /// (`benches/overlap.rs`) records exactly that crossover. Consumes
    /// the same retransmission draw as `sync` for a given fabric state.
    pub fn sync_overlapped(
        &mut self,
        topology: Topology,
        profiles: &[WorkerProfile],
        grad_bytes: usize,
        compute_s: f64,
        n_buckets: usize,
    ) -> SyncOutcome {
        let n = profiles.len();
        let congestion = self.congestion.value();
        if n <= 1 {
            return SyncOutcome {
                time_s: 0.0,
                retransmissions: 0,
                throughput_gbps: 0.0,
                congestion,
            };
        }
        let nb = n_buckets.max(1);
        let min_bw_gbps = profiles
            .iter()
            .map(|p| p.bandwidth_gbps)
            .fold(f64::INFINITY, f64::min);
        let max_lat_s = profiles
            .iter()
            .map(|p| p.latency_ms / 1e3)
            .fold(0.0f64, f64::max);
        let eff_bw_bytes = min_bw_gbps * (1.0 - congestion) * 1e9 / 8.0;

        let (alpha_per_bucket, bytes_on_wire) = match topology {
            Topology::RingAllReduce => {
                let hops = 2.0 * (n as f64 - 1.0);
                (hops * max_lat_s, hops / n as f64 * grad_bytes as f64)
            }
            Topology::ParameterServer { servers } => {
                let s = servers.max(1) as f64;
                (2.0 * max_lat_s, 2.0 * grad_bytes as f64 * (n as f64 / s))
            }
        };
        // Per-bucket transfer on the bottleneck link, serialized per hop.
        let bucket_transfer_s = bytes_on_wire / nb as f64 / eff_bw_bytes;
        let mut link_free = 0.0f64;
        for k in 0..nb {
            let ready = compute_s * (k + 1) as f64 / nb as f64;
            link_free = ready.max(link_free) + alpha_per_bucket + bucket_transfer_s;
        }
        let exposed_s = link_free - compute_s;

        let gib = bytes_on_wire * n as f64 / (1024.0 * 1024.0 * 1024.0);
        let lambda = self.retx_per_gib * gib * congestion;
        let retransmissions = self.rng.poisson(lambda);
        let retx_penalty = retransmissions as f64 * 1_500.0 / eff_bw_bytes * 4.0;
        let time_s = exposed_s + retx_penalty;

        SyncOutcome {
            time_s,
            retransmissions,
            throughput_gbps: if time_s > 0.0 {
                bytes_on_wire * 8.0 / 1e9 / time_s
            } else {
                0.0
            },
            congestion,
        }
    }

    /// Capture the full fabric state (checkpointing): the retransmission
    /// RNG stream, the OU congestion process, and the scalars `reset`
    /// would otherwise rebuild from the seed.
    pub fn snapshot(&self) -> NetSimState {
        NetSimState {
            rng: self.rng.state(),
            congestion: self.congestion.snapshot(),
            base_mean: self.base_mean,
            noisy: self.noisy,
            retx_per_gib: self.retx_per_gib,
        }
    }

    /// Overwrite every field from a [`NetSimState`]: the restored fabric
    /// continues the original trajectory bit-for-bit.
    pub fn restore(&mut self, s: &NetSimState) {
        self.rng = Rng::from_state(s.rng);
        self.congestion.restore(&s.congestion);
        self.base_mean = s.base_mean;
        self.noisy = s.noisy;
        self.retx_per_gib = s.retx_per_gib;
    }

    /// Reset the congestion process (new episode). Storm-shifted means
    /// restore to the construction baseline.
    pub fn reset(&mut self, seed: u64) {
        *self = if self.noisy {
            Self::noisy(seed)
        } else {
            Self::new(seed)
        };
    }
}

/// Serializable checkpoint image of a [`NetworkSim`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NetSimState {
    /// Retransmission-draw stream.
    pub rng: [u64; 4],
    /// Shared OU congestion process.
    pub congestion: ProcessState,
    /// Baseline congestion mean ([`NetworkSim::relax`] target).
    pub base_mean: f64,
    /// Construction flavour.
    pub noisy: bool,
    /// Retransmissions per (GiB × unit congestion).
    pub retx_per_gib: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::profiles;
    use crate::config::ClusterPreset;

    fn uniform(n: usize) -> Vec<WorkerProfile> {
        profiles(ClusterPreset::UniformA100, n, 0)
    }

    #[test]
    fn single_worker_needs_no_sync() {
        let mut net = NetworkSim::new(0);
        let o = net.sync(Topology::RingAllReduce, &uniform(1), 1 << 20);
        assert_eq!(o.time_s, 0.0);
        assert_eq!(o.retransmissions, 0);
    }

    #[test]
    fn ring_time_grows_sublinearly_with_workers() {
        // Ring moves 2(N-1)/N bytes — asymptotically constant per worker.
        let mut net = NetworkSim::new(0);
        net.set_congestion_vol(0.0); // deterministic
        let t8 = net.sync(Topology::RingAllReduce, &uniform(8), 100 << 20).time_s;
        let t32 = net.sync(Topology::RingAllReduce, &uniform(32), 100 << 20).time_s;
        assert!(t32 > t8, "latency terms grow");
        assert!(t32 < t8 * 2.0, "transfer term must not grow linearly");
    }

    #[test]
    fn ps_incast_slower_than_ring_at_scale() {
        let mut net = NetworkSim::new(0);
        net.set_congestion_vol(0.0);
        let profs = uniform(16);
        let ring = net.sync(Topology::RingAllReduce, &profs, 100 << 20).time_s;
        let ps = net
            .sync(Topology::ParameterServer { servers: 2 }, &profs, 100 << 20)
            .time_s;
        assert!(ps > ring, "ps {ps} vs ring {ring}");
    }

    #[test]
    fn more_servers_relieve_incast() {
        let mut net = NetworkSim::new(0);
        net.set_congestion_vol(0.0);
        let profs = uniform(16);
        let ps1 = net.sync(Topology::ParameterServer { servers: 1 }, &profs, 50 << 20).time_s;
        let ps4 = net.sync(Topology::ParameterServer { servers: 4 }, &profs, 50 << 20).time_s;
        assert!(ps4 < ps1);
    }

    #[test]
    fn congestion_slows_and_retransmits() {
        let mut a = NetworkSim::new(1);
        a.set_congestion(0.0);
        a.set_congestion_vol(0.0);
        let mut b = NetworkSim::new(1);
        b.set_congestion(0.6);
        b.set_congestion_vol(0.0);
        let profs = uniform(8);
        let oa = a.sync(Topology::RingAllReduce, &profs, 200 << 20);
        let ob = b.sync(Topology::RingAllReduce, &profs, 200 << 20);
        assert!(ob.time_s > oa.time_s * 1.5);
        assert!(ob.retransmissions > oa.retransmissions);
        assert!(ob.throughput_gbps < oa.throughput_gbps);
    }

    #[test]
    fn congestion_process_bounded_and_mean_reverting() {
        let mut net = NetworkSim::new(2);
        for _ in 0..200 {
            net.advance(0.5);
            assert!((0.0..=0.9).contains(&net.congestion()));
        }
        // Push far above mean; it must decay back.
        net.set_congestion(0.85);
        net.set_congestion_vol(0.0);
        for _ in 0..100 {
            net.advance(1.0);
        }
        assert!(net.congestion() < 0.3);
    }

    #[test]
    fn storm_persists_until_relax_then_decays() {
        let mut net = NetworkSim::new(4);
        net.set_congestion_vol(0.0);
        let base = net.congestion_mean();
        net.storm(0.8);
        assert_eq!(net.congestion(), 0.8);
        // The storm's shifted mean holds the level up.
        for _ in 0..50 {
            net.advance(1.0);
        }
        assert!(net.congestion() > 0.7, "storm decayed early: {}", net.congestion());
        net.relax();
        assert_eq!(net.congestion_mean(), base);
        for _ in 0..100 {
            net.advance(1.0);
        }
        assert!(net.congestion() < 0.2, "did not relax: {}", net.congestion());
    }

    #[test]
    fn reset_restores_baseline_after_storm() {
        let mut net = NetworkSim::noisy(5);
        net.storm(0.8);
        net.reset(5);
        assert!((net.congestion_mean() - 0.15).abs() < 1e-12, "noisy baseline");
        let mut quiet = NetworkSim::new(5);
        quiet.storm(0.8);
        quiet.reset(5);
        assert!((quiet.congestion_mean() - 0.05).abs() < 1e-12);
    }

    #[test]
    fn hetero_fabric_bound_by_slowest_nic() {
        let mut net = NetworkSim::new(3);
        net.set_congestion_vol(0.0);
        let fabric = profiles(ClusterPreset::FabricHetero, 8, 0);
        let fast = uniform(8);
        let tf = net.sync(Topology::RingAllReduce, &fabric, 100 << 20).time_s;
        let tu = net.sync(Topology::RingAllReduce, &fast, 100 << 20).time_s;
        assert!(tf > tu, "10G fabric must sync slower than 25G uniform");
    }

    #[test]
    fn overlapped_sync_hides_transfer_under_compute() {
        let fresh = || {
            let mut net = NetworkSim::new(7);
            net.set_congestion_vol(0.0);
            net.set_congestion(0.0); // lambda = 0: fully deterministic
            net
        };
        let profs = uniform(8);
        let bulk = fresh().sync(Topology::RingAllReduce, &profs, 100 << 20).time_s;
        // With the backward long enough to hide under, only the final
        // bucket's hop (plus its latency round) stays exposed.
        let exposed = fresh()
            .sync_overlapped(Topology::RingAllReduce, &profs, 100 << 20, bulk * 2.0, 16)
            .time_s;
        assert!(exposed < bulk, "exposed {exposed} vs bulk {bulk}");
        // One bucket ready only when compute ends == the bulk collective.
        let one = fresh()
            .sync_overlapped(Topology::RingAllReduce, &profs, 100 << 20, 1.0, 1)
            .time_s;
        assert!((one - bulk).abs() < 1e-12, "one-bucket {one} vs bulk {bulk}");
    }

    #[test]
    fn overlap_gains_grow_as_bandwidth_shrinks() {
        // The sweep the bench records: at constrained bandwidth the byte
        // term dominates and pipelining hides most of it; the absolute
        // saving (bulk - exposed) must grow as links slow down.
        let mut last_saving = -f64::INFINITY;
        for bw in [25.0, 10.0, 1.0] {
            let mut profs = uniform(8);
            for p in &mut profs {
                p.bandwidth_gbps = bw;
            }
            let mk = || {
                let mut net = NetworkSim::new(9);
                net.set_congestion_vol(0.0);
                net.set_congestion(0.0);
                net
            };
            let bulk = mk().sync(Topology::RingAllReduce, &profs, 64 << 20).time_s;
            let compute = bulk; // backward comparable to the collective
            let exposed = mk()
                .sync_overlapped(Topology::RingAllReduce, &profs, 64 << 20, compute, 8)
                .time_s;
            let saving = bulk - exposed;
            assert!(saving > last_saving, "saving shrank at {bw} Gbps: {saving}");
            last_saving = saving;
        }
    }

    #[test]
    fn snapshot_restore_resumes_bitwise_through_a_storm() {
        let profs = uniform(8);
        let mut net = NetworkSim::noisy(21);
        for _ in 0..15 {
            net.advance(0.4);
            net.sync(Topology::RingAllReduce, &profs, 64 << 20);
        }
        net.storm(0.7); // snapshot mid-storm: shifted mean must survive
        let snap = net.snapshot();
        let tail = |n: &mut NetworkSim| {
            let mut out = Vec::new();
            for i in 0..40 {
                n.advance(0.4);
                if i == 10 {
                    n.relax(); // relax must restore the ORIGINAL base mean
                }
                let o = n.sync(Topology::RingAllReduce, &profs, 64 << 20);
                out.push((o.time_s.to_bits(), o.retransmissions, o.congestion.to_bits()));
            }
            out
        };
        let want = tail(&mut net);
        let mut fresh = NetworkSim::new(0); // wrong seed + wrong flavour
        fresh.restore(&snap);
        assert_eq!(tail(&mut fresh), want);
    }

    #[test]
    fn deterministic_under_same_seed() {
        let run = |seed| {
            let mut net = NetworkSim::new(seed);
            let profs = uniform(8);
            (0..10)
                .map(|_| {
                    net.advance(0.1);
                    net.sync(Topology::RingAllReduce, &profs, 64 << 20).retransmissions
                })
                .collect::<Vec<_>>()
        };
        assert_eq!(run(5), run(5));
    }
}
