//! Gradient-synchronization network simulator.
//!
//! Models the two topologies the paper evaluates (§VI): decentralized
//! **Ring All-Reduce** (primary + OSC testbeds) and a BytePS-style
//! **parameter server** (§VI-G), with an alpha-beta collective cost model
//! plus a congestion/retransmission process. This produces the
//! network-level RL state features (throughput, retransmissions) whose
//! coupling to batch size — larger batches → fewer syncs → less exposure
//! to congestion — is the signal the paper's state design exploits (§IV-B).
//!
//! Cost model (alpha = latency term, beta = byte term):
//!   ring:  t = 2(N-1)·alpha + 2·(N-1)/N · bytes / min_bw
//!   ps:    t = 2·alpha + 2 · bytes · (N/servers) / bw   (incast at servers)
//! Congestion multiplies the effective bandwidth by (1 - c); cross-traffic
//! follows an OU process shared across links (a congested fabric slows
//! everyone, which is what the retransmission counters observe).

use crate::cluster::WorkerProfile;
use crate::config::Topology;
use crate::util::rng::Rng;

/// Result of simulating one synchronization round.
#[derive(Clone, Copy, Debug)]
pub struct SyncOutcome {
    /// Wall time of the collective in seconds.
    pub time_s: f64,
    /// Total TCP retransmissions observed across the round.
    pub retransmissions: u64,
    /// Achieved goodput in Gbit/s (bytes moved / time).
    pub throughput_gbps: f64,
    /// Congestion level in [0,1) during the round.
    pub congestion: f64,
}

/// Network fabric simulator with a shared congestion process.
pub struct NetworkSim {
    rng: Rng,
    /// OU congestion level in [0, 0.9].
    congestion: f64,
    pub congestion_mean: f64,
    pub congestion_rate: f64,
    pub congestion_vol: f64,
    /// Retransmissions per (GiB moved × unit congestion).
    pub retx_per_gib: f64,
}

impl NetworkSim {
    pub fn new(seed: u64) -> Self {
        NetworkSim {
            rng: Rng::new(seed ^ 0x4E75),
            congestion: 0.05,
            congestion_mean: 0.05,
            congestion_rate: 0.3,
            congestion_vol: 0.04,
            retx_per_gib: 900.0,
        }
    }

    /// A noisier fabric (FABRIC testbed / §VI-G heterogeneous cluster).
    pub fn noisy(seed: u64) -> Self {
        NetworkSim {
            congestion: 0.15,
            congestion_mean: 0.15,
            congestion_vol: 0.08,
            retx_per_gib: 2_500.0,
            ..Self::new(seed)
        }
    }

    /// Advance the shared congestion process by `dt` seconds.
    pub fn advance(&mut self, dt: f64) {
        let drift = self.congestion_rate * (self.congestion_mean - self.congestion) * dt;
        let diffusion = self.congestion_vol * dt.sqrt() * self.rng.normal();
        self.congestion = (self.congestion + drift + diffusion).clamp(0.0, 0.9);
    }

    pub fn congestion(&self) -> f64 {
        self.congestion
    }

    /// Simulate one gradient synchronization of `grad_bytes` per worker.
    pub fn sync(
        &mut self,
        topology: Topology,
        profiles: &[WorkerProfile],
        grad_bytes: usize,
    ) -> SyncOutcome {
        let n = profiles.len();
        if n <= 1 {
            return SyncOutcome {
                time_s: 0.0,
                retransmissions: 0,
                throughput_gbps: 0.0,
                congestion: self.congestion,
            };
        }
        // The slowest NIC and the largest latency bound the collective.
        let min_bw_gbps = profiles
            .iter()
            .map(|p| p.bandwidth_gbps)
            .fold(f64::INFINITY, f64::min);
        let max_lat_s = profiles
            .iter()
            .map(|p| p.latency_ms / 1e3)
            .fold(0.0f64, f64::max);
        let eff_bw_bytes = min_bw_gbps * (1.0 - self.congestion) * 1e9 / 8.0;

        let (alpha_terms, bytes_on_wire) = match topology {
            Topology::RingAllReduce => {
                // reduce-scatter + all-gather: 2(N-1) hops of bytes/N.
                let hops = 2.0 * (n as f64 - 1.0);
                (hops * max_lat_s, hops / n as f64 * grad_bytes as f64)
            }
            Topology::ParameterServer { servers } => {
                let s = servers.max(1) as f64;
                // push + pull; server NICs shared by N/s workers (incast).
                (2.0 * max_lat_s, 2.0 * grad_bytes as f64 * (n as f64 / s))
            }
        };
        let transfer_s = bytes_on_wire / eff_bw_bytes;
        let time_s = alpha_terms + transfer_s;

        // Retransmissions scale with bytes moved and congestion.
        let gib = bytes_on_wire * n as f64 / (1024.0 * 1024.0 * 1024.0);
        let lambda = self.retx_per_gib * gib * self.congestion;
        let retransmissions = self.rng.poisson(lambda);
        // Retransmitted segments add tail latency (~1.5 KB each + RTO slop).
        let retx_penalty = retransmissions as f64 * 1_500.0 / eff_bw_bytes * 4.0;
        let time_s = time_s + retx_penalty;

        SyncOutcome {
            time_s,
            retransmissions,
            throughput_gbps: if time_s > 0.0 {
                bytes_on_wire * 8.0 / 1e9 / time_s
            } else {
                0.0
            },
            congestion: self.congestion,
        }
    }

    /// Reset the congestion process (new episode).
    pub fn reset(&mut self, seed: u64) {
        *self = if self.congestion_mean > 0.1 {
            Self::noisy(seed)
        } else {
            Self::new(seed)
        };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::profiles;
    use crate::config::ClusterPreset;

    fn uniform(n: usize) -> Vec<WorkerProfile> {
        profiles(ClusterPreset::UniformA100, n, 0)
    }

    #[test]
    fn single_worker_needs_no_sync() {
        let mut net = NetworkSim::new(0);
        let o = net.sync(Topology::RingAllReduce, &uniform(1), 1 << 20);
        assert_eq!(o.time_s, 0.0);
        assert_eq!(o.retransmissions, 0);
    }

    #[test]
    fn ring_time_grows_sublinearly_with_workers() {
        // Ring moves 2(N-1)/N bytes — asymptotically constant per worker.
        let mut net = NetworkSim::new(0);
        net.congestion_vol = 0.0; // deterministic
        let t8 = net.sync(Topology::RingAllReduce, &uniform(8), 100 << 20).time_s;
        let t32 = net.sync(Topology::RingAllReduce, &uniform(32), 100 << 20).time_s;
        assert!(t32 > t8, "latency terms grow");
        assert!(t32 < t8 * 2.0, "transfer term must not grow linearly");
    }

    #[test]
    fn ps_incast_slower_than_ring_at_scale() {
        let mut net = NetworkSim::new(0);
        net.congestion_vol = 0.0;
        let profs = uniform(16);
        let ring = net.sync(Topology::RingAllReduce, &profs, 100 << 20).time_s;
        let ps = net
            .sync(Topology::ParameterServer { servers: 2 }, &profs, 100 << 20)
            .time_s;
        assert!(ps > ring, "ps {ps} vs ring {ring}");
    }

    #[test]
    fn more_servers_relieve_incast() {
        let mut net = NetworkSim::new(0);
        net.congestion_vol = 0.0;
        let profs = uniform(16);
        let ps1 = net.sync(Topology::ParameterServer { servers: 1 }, &profs, 50 << 20).time_s;
        let ps4 = net.sync(Topology::ParameterServer { servers: 4 }, &profs, 50 << 20).time_s;
        assert!(ps4 < ps1);
    }

    #[test]
    fn congestion_slows_and_retransmits() {
        let mut a = NetworkSim::new(1);
        a.congestion = 0.0;
        a.congestion_vol = 0.0;
        let mut b = NetworkSim::new(1);
        b.congestion = 0.6;
        b.congestion_vol = 0.0;
        let profs = uniform(8);
        let oa = a.sync(Topology::RingAllReduce, &profs, 200 << 20);
        let ob = b.sync(Topology::RingAllReduce, &profs, 200 << 20);
        assert!(ob.time_s > oa.time_s * 1.5);
        assert!(ob.retransmissions > oa.retransmissions);
        assert!(ob.throughput_gbps < oa.throughput_gbps);
    }

    #[test]
    fn congestion_process_bounded_and_mean_reverting() {
        let mut net = NetworkSim::new(2);
        for _ in 0..200 {
            net.advance(0.5);
            assert!((0.0..=0.9).contains(&net.congestion()));
        }
        // Push far above mean; it must decay back.
        net.congestion = 0.85;
        net.congestion_vol = 0.0;
        for _ in 0..100 {
            net.advance(1.0);
        }
        assert!(net.congestion() < 0.3);
    }

    #[test]
    fn hetero_fabric_bound_by_slowest_nic() {
        let mut net = NetworkSim::new(3);
        net.congestion_vol = 0.0;
        let fabric = profiles(ClusterPreset::FabricHetero, 8, 0);
        let fast = uniform(8);
        let tf = net.sync(Topology::RingAllReduce, &fabric, 100 << 20).time_s;
        let tu = net.sync(Topology::RingAllReduce, &fast, 100 << 20).time_s;
        assert!(tf > tu, "10G fabric must sync slower than 25G uniform");
    }

    #[test]
    fn deterministic_under_same_seed() {
        let run = |seed| {
            let mut net = NetworkSim::new(seed);
            let profs = uniform(8);
            (0..10)
                .map(|_| {
                    net.advance(0.1);
                    net.sync(Topology::RingAllReduce, &profs, 64 << 20).retransmissions
                })
                .collect::<Vec<_>>()
        };
        assert_eq!(run(5), run(5));
    }
}
