//! End-to-end iteration pipeline: data assembly + fused PJRT step +
//! simulators + window accounting — the paper's Table-level throughput.
//!
//!     cargo bench --bench pipeline

use dynamix::config::ExperimentConfig;
use dynamix::runtime::default_backend;
use dynamix::trainer::BspTrainer;
use dynamix::util::bench::{bench, throughput};

fn main() -> anyhow::Result<()> {
    let store = default_backend()?;
    for (workers, batch) in [(4usize, 64usize), (16, 64), (16, 256)] {
        let mut cfg = ExperimentConfig::default();
        cfg.cluster.n_workers = workers;
        cfg.batch.initial = batch;
        let mut t = BspTrainer::new(&cfg, store.clone())?;
        // Warm the bucket executable.
        t.iterate()?;
        let global = workers * batch;
        let r = bench(&format!("bsp_iteration/{workers}w-b{batch}"), 1, 8, || {
            t.iterate().unwrap();
        });
        println!("    -> {:.0} samples/s global batch {global}", throughput(&r, global));
    }

    println!("\n== eval step ==");
    let mut cfg = ExperimentConfig::default();
    cfg.cluster.n_workers = 4;
    let mut t = BspTrainer::new(&cfg, store)?;
    t.eval()?;
    bench("eval/1024", 1, 10, || {
        t.eval().unwrap();
    });
    Ok(())
}
