//! End-to-end iteration pipeline: data assembly + fused PJRT step +
//! simulators + window accounting — the paper's Table-level throughput.
//!
//!     cargo bench --bench pipeline

use dynamix::config::ExperimentConfig;
use dynamix::runtime::default_backend;
use dynamix::trainer::BspTrainer;
use dynamix::util::bench::{bench, iters, throughput, BenchSession};

fn main() -> anyhow::Result<()> {
    let store = default_backend()?;
    let mut session = BenchSession::new("pipeline");
    for (workers, batch) in [(4usize, 64usize), (16, 64), (16, 256)] {
        let mut cfg = ExperimentConfig::default();
        cfg.cluster.n_workers = workers;
        cfg.batch.initial = batch;
        let mut t = BspTrainer::new(&cfg, store.clone())?;
        // Warm the bucket executable.
        t.iterate()?;
        let global = workers * batch;
        let (w, n) = iters(1, 8);
        let r = bench(&format!("bsp_iteration/{workers}w-b{batch}"), w, n, || {
            t.iterate().unwrap();
        });
        println!("    -> {:.0} samples/s global batch {global}", throughput(&r, global));
        session.push_items(&r, global);
    }

    println!("\n== eval step ==");
    let mut cfg = ExperimentConfig::default();
    cfg.cluster.n_workers = 4;
    let mut t = BspTrainer::new(&cfg, store)?;
    t.eval()?;
    let (w, n) = iters(1, 10);
    let r = bench("eval/1024", w, n, || {
        t.eval().unwrap();
    });
    session.push_items(&r, 1024);

    let path = session.flush()?;
    println!("\nrecorded run -> {}", path.display());
    Ok(())
}
