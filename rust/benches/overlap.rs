//! Comm/compute overlap cost, two sessions:
//!
//! * `overlap` — **wall-clock** loopback train-step p50 with the pipelined
//!   bucket ring on vs. off (same shard count, same batches), per kernel
//!   tier. Records what the overlapped schedule costs/saves end-to-end on
//!   the in-process data plane, where the "network" is an mpsc channel and
//!   the win is bounded by how much send/serialize time the comm lane can
//!   hide behind the remaining backward stages.
//! * `overlap/bandwidth-sweep` — **simulated timeline (netsim), not
//!   wall-clock**: bulk [`NetworkSim::sync`] vs. pipelined
//!   [`NetworkSim::sync_overlapped`] exposed time across shrinking link
//!   bandwidth, congestion pinned to 0 so every number is a deterministic
//!   closed-form of the cost model and re-runs reproduce it bit-for-bit.
//!   This is the suite the regression gate watches: overlap-on must stay
//!   ≤ overlap-off at constrained bandwidth.
//!
//!     cargo bench --bench overlap
//!
//! The bandwidth-sweep result names encode the swept link speed
//! (`bw01gbps/bulk` vs `bw01gbps/overlapped`); savings grow as the link
//! shrinks because the byte term dominates the per-bucket latency tax.

use dynamix::cluster::profiles;
use dynamix::config::{ClusterPreset, Optimizer, Topology};
use dynamix::netsim::NetworkSim;
use dynamix::runtime::{ComputeBackend, KernelTier, OptState, ShardedBackend, TrainOut};
use dynamix::util::bench::{bench, iters, BenchResult, BenchSession};
use dynamix::util::rng::Rng;

/// One fused train step on `b`, timed over the whole optimizer cycle.
fn step(b: &ShardedBackend, state: &mut OptState, xs: &[f32], ys: &[i32], bucket: usize) {
    let mask = vec![1.0f32; bucket];
    let mut out = TrainOut::default();
    b.train_step_into(
        "vgg11_mini",
        Optimizer::Sgd,
        bucket,
        state,
        xs,
        ys,
        &mask,
        0.05,
        &mut out,
    )
    .unwrap();
}

fn main() -> anyhow::Result<()> {
    println!("== wall-clock: pipelined bucket ring on vs off (4 loopback shards) ==");
    let mut wall = BenchSession::new("overlap");
    let bucket = 256usize;
    let mut rng = Rng::new(0);
    for tier in KernelTier::available() {
        for (tag, overlap) in [("off", false), ("on", true)] {
            let backend = ShardedBackend::loopback_with_kernel(4, 1, tier)
                .with_overlap(overlap, 40 << 10);
            let fd = backend.schema().feature_dim;
            let xs: Vec<f32> = (0..bucket * fd).map(|_| rng.normal() as f32).collect();
            let ys: Vec<i32> = (0..bucket).map(|_| rng.below(10) as i32).collect();
            let mut state =
                OptState::new(backend.init_params("vgg11_mini", 0)?, Optimizer::Sgd);
            let (w, n) = iters(2, 8);
            let r = bench(
                &format!("train_step/{}-overlap-{tag}", tier.as_str()),
                w,
                n,
                || step(&backend, &mut state, &xs, &ys, bucket),
            );
            wall.push_items(&r, bucket);
        }
    }
    let path = wall.flush()?;
    println!("recorded run -> {}", path.display());

    println!("\n== simulated timeline: exposed comm vs link bandwidth (netsim) ==");
    // Deterministic: congestion pinned to 0 means no retransmission draw
    // and no OU noise — the recorded numbers are pure cost-model output
    // and identical on every re-run, so bench-compare deltas gate at 0%.
    let mut sweep = BenchSession::new("overlap/bandwidth-sweep");
    sweep.set_note(
        "simulated-timeline (netsim), not wall-clock; 8-node ring, 100 MiB grad, \
         compute 0.25s, 32 buckets, congestion pinned to 0 (deterministic)",
    );
    const GRAD_BYTES: usize = 100 << 20;
    const COMPUTE_S: f64 = 0.25;
    const N_BUCKETS: usize = 32;
    for bw_gbps in [25.0f64, 10.0, 5.0, 1.0] {
        let mut profs = profiles(ClusterPreset::UniformA100, 8, 0);
        for p in &mut profs {
            p.bandwidth_gbps = bw_gbps;
        }
        let mut net = NetworkSim::new(0);
        net.set_congestion_vol(0.0);
        net.set_congestion(0.0);
        let bulk = net.sync(Topology::RingAllReduce, &profs, GRAD_BYTES).time_s;
        let overlapped = net
            .sync_overlapped(Topology::RingAllReduce, &profs, GRAD_BYTES, COMPUTE_S, N_BUCKETS)
            .time_s;
        println!(
            "  {bw_gbps:>4.0} Gbps: bulk {:>9.2} ms  overlapped (exposed) {:>9.2} ms  ({:+.1}%)",
            bulk * 1e3,
            overlapped * 1e3,
            100.0 * (overlapped - bulk) / bulk,
        );
        for (tag, t) in [("bulk", bulk), ("overlapped", overlapped)] {
            sweep.push(&BenchResult {
                name: format!("bw{bw_gbps:02.0}gbps/{tag}"),
                mean_s: t,
                std_s: 0.0,
                min_s: t,
                p10_s: t,
                p50_s: t,
                p90_s: t,
                n: 1,
            });
        }
        assert!(
            overlapped <= bulk,
            "overlap must not lose at {bw_gbps} Gbps: {overlapped} vs {bulk}"
        );
    }
    let path = sweep.flush()?;
    println!("recorded run -> {}", path.display());
    Ok(())
}
