//! Network + cluster simulator cost: these run every simulated iteration,
//! so they must be orders of magnitude below the backend step cost.
//! Appends a run record to `BENCH_native.json`.
//!
//!     cargo bench --bench netsim

use dynamix::cluster::{profiles, SimCluster};
use dynamix::config::{ClusterPreset, Topology};
use dynamix::netsim::NetworkSim;
use dynamix::util::bench::{bench, iters, BenchSession};

fn main() -> anyhow::Result<()> {
    let mut session = BenchSession::new("netsim");
    println!("== collective cost model evaluations ==");
    for n in [8usize, 16, 32] {
        let profs = profiles(ClusterPreset::OscA100, n, 0);
        let mut net = NetworkSim::new(0);
        let (w, it) = iters(100, 2000);
        let r = bench(&format!("ring_allreduce/{n}nodes"), w, it, || {
            std::hint::black_box(net.sync(Topology::RingAllReduce, &profs, 37 << 20));
        });
        session.push(&r);
        let mut net = NetworkSim::new(0);
        let r = bench(&format!("param_server2/{n}nodes"), w, it, || {
            std::hint::black_box(net.sync(Topology::ParameterServer { servers: 2 }, &profs, 37 << 20));
        });
        session.push(&r);
    }

    println!("\n== cluster compute phase + clock advance ==");
    for n in [8usize, 32] {
        let mut c = SimCluster::new(ClusterPreset::FabricHetero, n, 0);
        let batches = vec![256usize; n];
        let (w, it) = iters(100, 2000);
        let r = bench(&format!("compute_phase/{n}nodes"), w, it, || {
            let out = c.compute_phase(&batches);
            c.advance_iteration(&out, 0.01);
        });
        session.push(&r);
    }

    println!("\n== synthetic data generation (batch assembly input) ==");
    let d = dynamix::data::SyntheticDataset::new(10, 128, 50_000, 0);
    let mut x = vec![0.0f32; 128];
    let (w, it) = iters(1000, 20000);
    let r = bench("sample_into/1", w, it, || {
        std::hint::black_box(d.sample_into(123, &mut x));
    });
    session.push(&r);
    let idx: Vec<u64> = (0..1024).collect();
    let (w, it) = iters(3, 30);
    let r = bench("batch/1024", w, it, || {
        std::hint::black_box(d.batch(&idx));
    });
    session.push_items(&r, 1024);

    let path = session.flush()?;
    println!("\nrecorded run -> {}", path.display());
    Ok(())
}
