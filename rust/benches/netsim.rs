//! Network + cluster simulator cost: these run every simulated iteration,
//! so they must be orders of magnitude below the PJRT step cost.
//!
//!     cargo bench --bench netsim

use dynamix::cluster::{profiles, SimCluster};
use dynamix::config::{ClusterPreset, Topology};
use dynamix::netsim::NetworkSim;
use dynamix::util::bench::bench;

fn main() {
    println!("== collective cost model evaluations ==");
    for n in [8usize, 16, 32] {
        let profs = profiles(ClusterPreset::OscA100, n, 0);
        let mut net = NetworkSim::new(0);
        bench(&format!("ring_allreduce/{n}nodes"), 100, 2000, || {
            std::hint::black_box(net.sync(Topology::RingAllReduce, &profs, 37 << 20));
        });
        let mut net = NetworkSim::new(0);
        bench(&format!("param_server2/{n}nodes"), 100, 2000, || {
            std::hint::black_box(net.sync(Topology::ParameterServer { servers: 2 }, &profs, 37 << 20));
        });
    }

    println!("\n== cluster compute phase + clock advance ==");
    for n in [8usize, 32] {
        let mut c = SimCluster::new(ClusterPreset::FabricHetero, n, 0);
        let batches = vec![256usize; n];
        bench(&format!("compute_phase/{n}nodes"), 100, 2000, || {
            let out = c.compute_phase(&batches);
            c.advance_iteration(&out, 0.01);
        });
    }

    println!("\n== synthetic data generation (batch assembly input) ==");
    let d = dynamix::data::SyntheticDataset::new(10, 128, 50_000, 0);
    let mut x = vec![0.0f32; 128];
    bench("sample_into/1", 1000, 20000, || {
        std::hint::black_box(d.sample_into(123, &mut x));
    });
    let idx: Vec<u64> = (0..1024).collect();
    bench("batch/1024", 3, 30, || {
        std::hint::black_box(d.batch(&idx));
    });
}
