//! §VI-H hot path: the arbitrator decision cycle.
//! state assembly -> policy_forward -> action sampling, plus the PPO
//! minibatch update. The overhead claim (decision < 0.1% of iteration
//! time) is checked against the measured train_step cost. Appends a run
//! record to `BENCH_native.json`.
//!
//!     cargo bench --bench decision_cycle

use dynamix::config::{ExperimentConfig, RlConfig};
use dynamix::rl::agent::PpoAgent;
use dynamix::rl::state::{GlobalState, StateBuilder, StateVector};
use dynamix::rl::trajectory::{Trajectory, Transition, UpdateBatch};
use dynamix::runtime::default_backend;
use dynamix::sim::scenario::{ScenarioEvent, ScenarioScript, TimedEvent};
use dynamix::sysmetrics::WindowSummary;
use dynamix::trainer::BspTrainer;
use dynamix::util::bench::{bench, iters, BenchSession};

fn main() -> anyhow::Result<()> {
    let store = default_backend()?;
    let mut session = BenchSession::new("decision_cycle");

    println!("== state vector assembly ==");
    let builder = StateBuilder::default();
    let summary = WindowSummary {
        acc_mean: 0.6,
        acc_std: 0.05,
        acc_gain: 0.4,
        iter_time_mean: 0.12,
        throughput_mean: 9.0,
        retransmissions: 40.0,
        cpu_time_ratio: 2.4,
        mem_util: 0.5,
        sigma_norm: 0.9,
        sigma_norm2: 0.81,
        loss_mean: 1.4,
        iters: 5,
    };
    let global = GlobalState {
        loss: 1.4,
        eval_acc: 0.6,
        eval_trend: 0.01,
        progress: 0.4,
        n_workers: 16,
    };
    let (w0, n0) = iters(100, 1000);
    let r = bench("state_build/16workers", w0, n0, || {
        for w in 0..16 {
            std::hint::black_box(builder.build(&summary, 128 + w, &global));
        }
    });
    session.push(&r);

    println!("\n== policy inference (one fused call scores all workers) ==");
    for n in [8usize, 16, 32] {
        let mut agent = PpoAgent::new(store.clone(), RlConfig::default(), 0)?;
        let states: Vec<StateVector> = (0..n)
            .map(|w| builder.build(&summary, 64 + w * 16, &global))
            .collect();
        let (w, it) = iters(5, 50);
        let r = bench(&format!("policy_forward/{n}workers"), w, it, || {
            agent.act(&states, false).unwrap();
        });
        session.push_items(&r, n);
    }

    println!("\n== PPO update (one epoch over 16x20 transitions) ==");
    let mut agent = PpoAgent::new(store.clone(), RlConfig { update_epochs: 1, ..Default::default() }, 0)?;
    let trajs: Vec<Trajectory> = (0..16)
        .map(|w| {
            let mut t = Trajectory::default();
            for i in 0..20 {
                t.push(Transition {
                    state: builder.build(&summary, 64 + i, &global),
                    action: (w + i) % 5,
                    logp: -1.6,
                    value: 0.1,
                    reward: 0.5,
                });
            }
            t
        })
        .collect();
    let batch = UpdateBatch::from_trajectories(&trajs, 0.99, 0.95);
    let (w, n) = iters(2, 10);
    let r = bench("policy_update/320x1epoch", w, n, || {
        agent.update(&batch).unwrap();
    });
    session.push_items(&r, 320);

    println!("\n== BSP iterate under scripted dynamics (event-queue overhead) ==");
    // Three operating points, all with 8 workers at batch 64:
    //  * steady            — no script (baseline iterate cost);
    //  * load_shift_storm  — several events due EVERY iteration, none of
    //    which touch membership or batches: the delta vs steady is the
    //    pure scenario-engine overhead on the hot loop;
    //  * preempt_churn     — full elastic churn (redistribute + reshard):
    //    the real cost of membership changes, dominated by the 50k-index
    //    shard reshuffle.
    let mk_cfg = |scenario: Option<ScenarioScript>| {
        let mut c = ExperimentConfig::default();
        c.cluster.n_workers = 8;
        c.batch.initial = 64;
        c.scenario = scenario;
        c
    };
    let (w, n) = iters(5, 60);
    let mut steady = BspTrainer::new(&mk_cfg(None), store.clone())?;
    let r = bench("iterate/steady", w, n, || {
        steady.iterate().unwrap();
    });
    session.push_items(&r, 8 * 64);

    // ~20k load-shift events at 2 ms spacing: the queue stays busy for the
    // whole measured horizon (quick mode included).
    let shifts = ScenarioScript {
        name: "bench-load-shift-storm".into(),
        events: (0..20_000)
            .map(|i| TimedEvent {
                at_s: (i + 1) as f64 * 0.002,
                event: ScenarioEvent::LoadShift {
                    worker: i % 8,
                    load_mean: if i % 2 == 0 { 0.5 } else { 0.1 },
                },
            })
            .collect(),
    };
    let mut shifted = BspTrainer::new(&mk_cfg(Some(shifts)), store.clone())?;
    let r = bench("iterate/load_shift_storm", w, n, || {
        shifted.iterate().unwrap();
    });
    session.push_items(&r, 8 * 64);

    // Rotating preempt/rejoin pairs (+ shifts) every ~10 ms; the cluster
    // never empties. Batches drift as budgets redistribute — this bench
    // prices the membership machinery, not a fixed batch shape.
    let churn = ScenarioScript::synthetic_churn(8, 20_000, 0.01);
    let mut churned = BspTrainer::new(&mk_cfg(Some(churn)), store.clone())?;
    let r = bench("iterate/preempt_churn", w, n, || {
        churned.iterate().unwrap();
    });
    session.push_items(&r, 8 * 64);

    println!("\n== sharded loopback data plane (n=4) vs single-process iterate ==");
    // Same operating point as iterate/steady, executed through the
    // 4-shard loopback backend: the delta is the data plane's overhead
    // (row scatter, per-step param snapshot, channel hops, and the
    // sequential chained gradient reduction) for bit-identical results.
    let sharded: dynamix::runtime::Backend =
        std::sync::Arc::new(dynamix::runtime::ShardedBackend::loopback(4));
    let mut shd = BspTrainer::new(&mk_cfg(None), sharded)?;
    let r = bench("iterate/sharded_loopback_n4", w, n, || {
        shd.iterate().unwrap();
    });
    session.push_items(&r, 8 * 64);

    let path = session.flush()?;
    println!("\nrecorded run -> {}", path.display());
    Ok(())
}
