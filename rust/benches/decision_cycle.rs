//! §VI-H hot path: the arbitrator decision cycle.
//! state assembly -> policy_forward -> action sampling, plus the PPO
//! minibatch update. The overhead claim (decision < 0.1% of iteration
//! time) is checked against the measured train_step cost. Appends a run
//! record to `BENCH_native.json`.
//!
//!     cargo bench --bench decision_cycle

use dynamix::config::RlConfig;
use dynamix::rl::agent::PpoAgent;
use dynamix::rl::state::{GlobalState, StateBuilder, StateVector};
use dynamix::rl::trajectory::{Trajectory, Transition, UpdateBatch};
use dynamix::runtime::default_backend;
use dynamix::sysmetrics::WindowSummary;
use dynamix::util::bench::{bench, iters, BenchSession};

fn main() -> anyhow::Result<()> {
    let store = default_backend()?;
    let mut session = BenchSession::new("decision_cycle");

    println!("== state vector assembly ==");
    let builder = StateBuilder::default();
    let summary = WindowSummary {
        acc_mean: 0.6,
        acc_std: 0.05,
        acc_gain: 0.4,
        iter_time_mean: 0.12,
        throughput_mean: 9.0,
        retransmissions: 40.0,
        cpu_time_ratio: 2.4,
        mem_util: 0.5,
        sigma_norm: 0.9,
        sigma_norm2: 0.81,
        loss_mean: 1.4,
        iters: 5,
    };
    let global = GlobalState {
        loss: 1.4,
        eval_acc: 0.6,
        eval_trend: 0.01,
        progress: 0.4,
        n_workers: 16,
    };
    let (w0, n0) = iters(100, 1000);
    let r = bench("state_build/16workers", w0, n0, || {
        for w in 0..16 {
            std::hint::black_box(builder.build(&summary, 128 + w, &global));
        }
    });
    session.push(&r);

    println!("\n== policy inference (one fused call scores all workers) ==");
    for n in [8usize, 16, 32] {
        let mut agent = PpoAgent::new(store.clone(), RlConfig::default(), 0)?;
        let states: Vec<StateVector> = (0..n)
            .map(|w| builder.build(&summary, 64 + w * 16, &global))
            .collect();
        let (w, it) = iters(5, 50);
        let r = bench(&format!("policy_forward/{n}workers"), w, it, || {
            agent.act(&states, false).unwrap();
        });
        session.push_items(&r, n);
    }

    println!("\n== PPO update (one epoch over 16x20 transitions) ==");
    let mut agent = PpoAgent::new(store.clone(), RlConfig { update_epochs: 1, ..Default::default() }, 0)?;
    let trajs: Vec<Trajectory> = (0..16)
        .map(|w| {
            let mut t = Trajectory::default();
            for i in 0..20 {
                t.push(Transition {
                    state: builder.build(&summary, 64 + i, &global),
                    action: (w + i) % 5,
                    logp: -1.6,
                    value: 0.1,
                    reward: 0.5,
                });
            }
            t
        })
        .collect();
    let batch = UpdateBatch::from_trajectories(&trajs, 0.99, 0.95);
    let (w, n) = iters(2, 10);
    let r = bench("policy_update/320x1epoch", w, n, || {
        agent.update(&batch).unwrap();
    });
    session.push_items(&r, 320);

    let path = session.flush()?;
    println!("\nrecorded run -> {}", path.display());
    Ok(())
}
