//! Design-choice ablations (DESIGN.md §6), timing side:
//!  * clipped PPO vs the paper's simplified update;
//!  * temporal-aggregation window k (decision overhead amortization);
//!  * fused policy_forward for N workers vs N separate calls.
//!
//!     cargo bench --bench ablations

use dynamix::config::{PpoVariant, RlConfig};
use dynamix::rl::agent::PpoAgent;
use dynamix::rl::state::{GlobalState, StateBuilder, StateVector};
use dynamix::rl::trajectory::{Trajectory, Transition, UpdateBatch};
use dynamix::runtime::default_backend;
use dynamix::sysmetrics::WindowSummary;
use dynamix::util::bench::{bench, iters, BenchSession};

fn main() -> anyhow::Result<()> {
    let store = default_backend()?;
    let mut session = BenchSession::new("ablations");
    let builder = StateBuilder::default();
    let summary = WindowSummary { acc_mean: 0.5, iter_time_mean: 0.1, ..Default::default() };
    let global = GlobalState { n_workers: 16, ..Default::default() };

    println!("== PPO variant update cost ==");
    let trajs: Vec<Trajectory> = (0..16)
        .map(|w| {
            let mut t = Trajectory::default();
            for i in 0..20 {
                t.push(Transition {
                    state: builder.build(&summary, 64 + i, &global),
                    action: (w + i) % 5,
                    logp: -1.6,
                    value: 0.1,
                    reward: 0.5,
                });
            }
            t
        })
        .collect();
    let batch = UpdateBatch::from_trajectories(&trajs, 0.99, 0.95);
    for variant in [PpoVariant::Clipped, PpoVariant::Simplified] {
        let mut agent = PpoAgent::new(
            store.clone(),
            RlConfig { variant, update_epochs: 1, ..Default::default() },
            0,
        )?;
        let (w, n) = iters(2, 10);
        let r = bench(&format!("update/{variant:?}"), w, n, || {
            agent.update(&batch).unwrap();
        });
        session.push(&r);
    }

    println!("\n== fused forward (32 workers, 1 call) vs 32 single-row calls ==");
    let mut agent = PpoAgent::new(store.clone(), RlConfig::default(), 0)?;
    let states: Vec<StateVector> = (0..32)
        .map(|w| builder.build(&summary, 64 + w * 8, &global))
        .collect();
    let (w, n) = iters(5, 40);
    let r = bench("forward/fused32", w, n, || {
        agent.act(&states, false).unwrap();
    });
    session.push(&r);
    let (w, n) = iters(2, 10);
    let r = bench("forward/32x1", w, n, || {
        for s in &states {
            agent.act(std::slice::from_ref(s), false).unwrap();
        }
    });
    session.push(&r);

    let path = session.flush()?;
    println!("\nrecorded run -> {}", path.display());
    Ok(())
}
