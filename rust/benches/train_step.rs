//! L3 hot path: the backend train-step execution across the bucket ladder.
//! Regenerates the per-iteration compute-cost column used to calibrate the
//! cluster simulator, and the padding-overhead ablation (same 100 valid
//! samples at growing buckets). Also sweeps the kernel tiers explicitly
//! (scalar/blocked/simd backends pinned per entry, independent of
//! `DYNAMIX_KERNEL`) and prices the persistent worker pool against the old
//! scoped-spawn execution at a small-bucket matmul, recording the delta in
//! the session's `note` field. Appends a machine-readable run record
//! (bucket, samples/s, p10/p50/p90, thread count, kernel tier, git rev) to
//! `BENCH_native.json` — the repo's perf trajectory.
//!
//!     cargo bench --bench train_step
//!     DYNAMIX_KERNEL=blocked DYNAMIX_BENCH_NOTE=pre-simd cargo bench --bench train_step

use dynamix::runtime::native::exec::{run_scoped, KernelTier, Pool};
use dynamix::runtime::native::linalg::matmul_acc;
use dynamix::runtime::{default_backend, Backend, NativeBackend};
use dynamix::trainer::ModelRuntime;
use dynamix::util::bench::{bench, iters, throughput, BenchSession};
use dynamix::util::rng::Rng;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let store = default_backend()?;
    let fd = store.schema().feature_dim;
    let mut rng = Rng::new(0);
    let mut session = BenchSession::new("train_step");

    println!("== train_step cost across buckets (vgg11_mini / sgd) ==");
    for bucket in [32usize, 128, 512, 1024, 4096] {
        let mut rt = ModelRuntime::new(
            store.clone(),
            "vgg11_mini",
            dynamix::config::Optimizer::Sgd,
            0.05,
            0,
        )?;
        let xs: Vec<f32> = (0..bucket * fd).map(|_| rng.normal() as f32).collect();
        let ys: Vec<i32> = (0..bucket).map(|_| rng.below(10) as i32).collect();
        let (w, n) = iters(2, 8);
        let r = bench(&format!("train_step/b{bucket}"), w, n, || {
            rt.train_step(&xs, &ys, bucket, bucket).unwrap();
        });
        println!("    -> {:.0} samples/s", throughput(&r, bucket));
        session.push_items(&r, bucket);
    }

    println!("\n== padding overhead: 100 valid samples in growing buckets ==");
    for bucket in [128usize, 192, 256, 512] {
        let mut rt = ModelRuntime::new(
            store.clone(),
            "vgg11_mini",
            dynamix::config::Optimizer::Sgd,
            0.05,
            0,
        )?;
        let xs: Vec<f32> = (0..bucket * fd).map(|_| rng.normal() as f32).collect();
        let ys: Vec<i32> = (0..bucket).map(|_| rng.below(10) as i32).collect();
        let (w, n) = iters(2, 8);
        let r = bench(&format!("pad100/b{bucket}"), w, n, || {
            rt.train_step(&xs, &ys, 100, bucket).unwrap();
        });
        session.push_items(&r, 100);
    }

    println!("\n== optimizer comparison at b256 ==");
    for opt in [dynamix::config::Optimizer::Sgd, dynamix::config::Optimizer::Adam] {
        let mut rt = ModelRuntime::new(store.clone(), "vgg11_mini", opt, 0.01, 0)?;
        let bucket = 256;
        let xs: Vec<f32> = (0..bucket * fd).map(|_| rng.normal() as f32).collect();
        let ys: Vec<i32> = (0..bucket).map(|_| rng.below(10) as i32).collect();
        let (w, n) = iters(2, 8);
        let r = bench(&format!("train_step/{}-b256", opt.as_str()), w, n, || {
            rt.train_step(&xs, &ys, bucket, bucket).unwrap();
        });
        session.push_items(&r, bucket);
    }

    println!("\n== kernel tiers (pinned per entry; small + large bucket) ==");
    // Per-tier session entries, independent of DYNAMIX_KERNEL: the same
    // train step through each executable tier at the process thread count.
    let threads = Pool::global().threads();
    for tier in KernelTier::available() {
        let backend: Backend = Arc::new(NativeBackend::with_kernel(threads, tier));
        for bucket in [32usize, 512] {
            let mut rt = ModelRuntime::new(
                backend.clone(),
                "vgg11_mini",
                dynamix::config::Optimizer::Sgd,
                0.05,
                0,
            )?;
            let xs: Vec<f32> = (0..bucket * fd).map(|_| rng.normal() as f32).collect();
            let ys: Vec<i32> = (0..bucket).map(|_| rng.below(10) as i32).collect();
            let (w, n) = iters(2, 8);
            let r = bench(
                &format!("train_step/{}-b{bucket}", tier.as_str()),
                w,
                n,
                || {
                    rt.train_step(&xs, &ys, bucket, bucket).unwrap();
                },
            );
            session.push_items(&r, bucket);
        }
    }

    println!("\n== persistent pool vs scoped-spawn at a small-bucket matmul ==");
    // The pool's reason to exist: at small problems the per-call
    // thread::scope spawns used to dominate. Same chunk plan, same blocked
    // kernels; only the execution strategy differs.
    {
        let (m, k, n) = (256usize, 128, 64);
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let mut out = vec![0.0f32; m * n];
        let pool = Pool::with_config(threads, KernelTier::Blocked);
        let per = pool.rows_per_chunk(m, 2 * k * n);
        let (wu, it) = iters(20, 200);
        let r_pool = bench("exec/pool_matmul_256x128x64", wu, it, || {
            out.fill(0.0);
            matmul_acc(&pool, &x, &w, m, k, n, &mut out);
        });
        let seq = Pool::with_config(1, KernelTier::Blocked);
        let wref: &[f32] = &w;
        let r_spawn = bench("exec/scoped_spawn_matmul_256x128x64", wu, it, || {
            out.fill(0.0);
            run_scoped(
                x.chunks(per * k)
                    .zip(out.chunks_mut(per * n))
                    .map(|(xc, oc)| {
                        let seq = seq.clone();
                        move || matmul_acc(&seq, xc, wref, xc.len() / k, k, n, oc)
                    })
                    .collect(),
            );
        });
        session.push(&r_pool);
        session.push(&r_spawn);
        let delta = 100.0 * (r_spawn.p50_s - r_pool.p50_s) / r_spawn.p50_s;
        session.set_note(&format!(
            "pool-vs-spawn @256x128x64 t{threads}: pool p50 {:.1}us vs scoped {:.1}us ({delta:+.0}% vs spawn)",
            r_pool.p50_s * 1e6,
            r_spawn.p50_s * 1e6,
        ));
    }

    let path = session.flush()?;
    println!("\nrecorded run -> {}", path.display());
    Ok(())
}
