//! L3 hot path: the backend train-step execution across the bucket ladder.
//! Regenerates the per-iteration compute-cost column used to calibrate the
//! cluster simulator, and the padding-overhead ablation (same 100 valid
//! samples at growing buckets). Appends a machine-readable run record
//! (bucket, samples/s, p10/p50/p90, thread count, git rev) to
//! `BENCH_native.json` — the repo's perf trajectory.
//!
//!     cargo bench --bench train_step
//!     DYNAMIX_THREADS=1 DYNAMIX_BENCH_NOTE=scalar cargo bench --bench train_step

use dynamix::runtime::default_backend;
use dynamix::trainer::ModelRuntime;
use dynamix::util::bench::{bench, iters, throughput, BenchSession};
use dynamix::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let store = default_backend()?;
    let fd = store.schema().feature_dim;
    let mut rng = Rng::new(0);
    let mut session = BenchSession::new("train_step");

    println!("== train_step cost across buckets (vgg11_mini / sgd) ==");
    for bucket in [32usize, 128, 512, 1024, 4096] {
        let mut rt = ModelRuntime::new(
            store.clone(),
            "vgg11_mini",
            dynamix::config::Optimizer::Sgd,
            0.05,
            0,
        )?;
        let xs: Vec<f32> = (0..bucket * fd).map(|_| rng.normal() as f32).collect();
        let ys: Vec<i32> = (0..bucket).map(|_| rng.below(10) as i32).collect();
        let (w, n) = iters(2, 8);
        let r = bench(&format!("train_step/b{bucket}"), w, n, || {
            rt.train_step(&xs, &ys, bucket, bucket).unwrap();
        });
        println!("    -> {:.0} samples/s", throughput(&r, bucket));
        session.push_items(&r, bucket);
    }

    println!("\n== padding overhead: 100 valid samples in growing buckets ==");
    for bucket in [128usize, 192, 256, 512] {
        let mut rt = ModelRuntime::new(
            store.clone(),
            "vgg11_mini",
            dynamix::config::Optimizer::Sgd,
            0.05,
            0,
        )?;
        let xs: Vec<f32> = (0..bucket * fd).map(|_| rng.normal() as f32).collect();
        let ys: Vec<i32> = (0..bucket).map(|_| rng.below(10) as i32).collect();
        let (w, n) = iters(2, 8);
        let r = bench(&format!("pad100/b{bucket}"), w, n, || {
            rt.train_step(&xs, &ys, 100, bucket).unwrap();
        });
        session.push_items(&r, 100);
    }

    println!("\n== optimizer comparison at b256 ==");
    for opt in [dynamix::config::Optimizer::Sgd, dynamix::config::Optimizer::Adam] {
        let mut rt = ModelRuntime::new(store.clone(), "vgg11_mini", opt, 0.01, 0)?;
        let bucket = 256;
        let xs: Vec<f32> = (0..bucket * fd).map(|_| rng.normal() as f32).collect();
        let ys: Vec<i32> = (0..bucket).map(|_| rng.below(10) as i32).collect();
        let (w, n) = iters(2, 8);
        let r = bench(&format!("train_step/{}-b256", opt.as_str()), w, n, || {
            rt.train_step(&xs, &ys, bucket, bucket).unwrap();
        });
        session.push_items(&r, bucket);
    }

    let path = session.flush()?;
    println!("\nrecorded run -> {}", path.display());
    Ok(())
}
