//! L3 hot path: the backend train-step execution across the bucket ladder.
//! Regenerates the per-iteration compute-cost column used to calibrate the
//! cluster simulator, and the padding-overhead ablation (same 100 valid
//! samples at growing buckets). Also sweeps the kernel tiers explicitly
//! (scalar/blocked/simd backends pinned per entry, independent of
//! `DYNAMIX_KERNEL`) and prices the persistent worker pool against the old
//! scoped-spawn execution at a small-bucket matmul, recording the delta in
//! the session's `note` field. The non-GEMM hot path gets its own entries:
//! tiered elementwise/row-softmax/optimizer kernels (`ops/*` per tier) and
//! the wire codecs (`wire/topk_select`, `wire/q8_codec` at the ambient
//! process tier). Appends a machine-readable run record
//! (bucket, samples/s, p10/p50/p90, thread count, kernel tier, git rev) to
//! `BENCH_native.json` — the repo's perf trajectory.
//!
//!     cargo bench --bench train_step
//!     DYNAMIX_KERNEL=blocked DYNAMIX_BENCH_NOTE=pre-simd cargo bench --bench train_step

use dynamix::comm::wire;
use dynamix::runtime::native::exec::{run_scoped, KernelTier, Pool};
use dynamix::runtime::native::linalg::{adam_apply, log_softmax, matmul_acc, relu};
use dynamix::runtime::{default_backend, Backend, NativeBackend};
use dynamix::trainer::ModelRuntime;
use dynamix::util::bench::{bench, iters, throughput, BenchSession};
use dynamix::util::rng::Rng;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let store = default_backend()?;
    let fd = store.schema().feature_dim;
    let mut rng = Rng::new(0);
    let mut session = BenchSession::new("train_step");

    println!("== train_step cost across buckets (vgg11_mini / sgd) ==");
    for bucket in [32usize, 128, 512, 1024, 4096] {
        let mut rt = ModelRuntime::new(
            store.clone(),
            "vgg11_mini",
            dynamix::config::Optimizer::Sgd,
            0.05,
            0,
        )?;
        let xs: Vec<f32> = (0..bucket * fd).map(|_| rng.normal() as f32).collect();
        let ys: Vec<i32> = (0..bucket).map(|_| rng.below(10) as i32).collect();
        let (w, n) = iters(2, 8);
        let r = bench(&format!("train_step/b{bucket}"), w, n, || {
            rt.train_step(&xs, &ys, bucket, bucket).unwrap();
        });
        println!("    -> {:.0} samples/s", throughput(&r, bucket));
        session.push_items(&r, bucket);
    }

    println!("\n== padding overhead: 100 valid samples in growing buckets ==");
    for bucket in [128usize, 192, 256, 512] {
        let mut rt = ModelRuntime::new(
            store.clone(),
            "vgg11_mini",
            dynamix::config::Optimizer::Sgd,
            0.05,
            0,
        )?;
        let xs: Vec<f32> = (0..bucket * fd).map(|_| rng.normal() as f32).collect();
        let ys: Vec<i32> = (0..bucket).map(|_| rng.below(10) as i32).collect();
        let (w, n) = iters(2, 8);
        let r = bench(&format!("pad100/b{bucket}"), w, n, || {
            rt.train_step(&xs, &ys, 100, bucket).unwrap();
        });
        session.push_items(&r, 100);
    }

    println!("\n== optimizer comparison at b256 ==");
    for opt in [dynamix::config::Optimizer::Sgd, dynamix::config::Optimizer::Adam] {
        let mut rt = ModelRuntime::new(store.clone(), "vgg11_mini", opt, 0.01, 0)?;
        let bucket = 256;
        let xs: Vec<f32> = (0..bucket * fd).map(|_| rng.normal() as f32).collect();
        let ys: Vec<i32> = (0..bucket).map(|_| rng.below(10) as i32).collect();
        let (w, n) = iters(2, 8);
        let r = bench(&format!("train_step/{}-b256", opt.as_str()), w, n, || {
            rt.train_step(&xs, &ys, bucket, bucket).unwrap();
        });
        session.push_items(&r, bucket);
    }

    println!("\n== kernel tiers (pinned per entry; small + large bucket) ==");
    // Per-tier session entries, independent of DYNAMIX_KERNEL: the same
    // train step through each executable tier at the process thread count.
    let threads = Pool::global().threads();
    for tier in KernelTier::available() {
        let backend: Backend = Arc::new(NativeBackend::with_kernel(threads, tier));
        for bucket in [32usize, 512] {
            let mut rt = ModelRuntime::new(
                backend.clone(),
                "vgg11_mini",
                dynamix::config::Optimizer::Sgd,
                0.05,
                0,
            )?;
            let xs: Vec<f32> = (0..bucket * fd).map(|_| rng.normal() as f32).collect();
            let ys: Vec<i32> = (0..bucket).map(|_| rng.below(10) as i32).collect();
            let (w, n) = iters(2, 8);
            let r = bench(
                &format!("train_step/{}-b{bucket}", tier.as_str()),
                w,
                n,
                || {
                    rt.train_step(&xs, &ys, bucket, bucket).unwrap();
                },
            );
            session.push_items(&r, bucket);
        }
    }

    println!("\n== non-GEMM ops per tier (elementwise / row-softmax / optimizer) ==");
    // The tiered elementwise/optimizer kernels, pinned per entry like the
    // train-step tier sweep. Sizes sit past the pool's parallel cutoff so
    // the chunked fan-out (not just the SIMD lanes) is on the clock.
    for tier in KernelTier::available() {
        let pool = Pool::with_config(threads, tier);
        let len = 1 << 18; // 256k floats
        let base: Vec<f32> = (0..len).map(|_| rng.normal() as f32).collect();
        let mut buf = base.clone();
        let (wu, it) = iters(10, 60);
        let r = bench(&format!("ops/relu/{}", tier.as_str()), wu, it, || {
            buf.copy_from_slice(&base);
            relu(&pool, &mut buf);
        });
        session.push_items(&r, len);

        let (m, n) = (2048usize, 128usize);
        let logits: Vec<f32> = (0..m * n).map(|_| rng.normal() as f32).collect();
        let mut logp = vec![0.0f32; m * n];
        let r = bench(&format!("ops/log_softmax/{}", tier.as_str()), wu, it, || {
            log_softmax(&pool, &logits, m, n, &mut logp);
        });
        session.push_items(&r, m);

        let g: Vec<f32> = (0..len).map(|_| rng.normal() as f32).collect();
        let mut params: Vec<f32> = (0..len).map(|_| rng.normal() as f32).collect();
        let mut mm = vec![0.0f32; len];
        let mut vv = vec![0.0f32; len];
        let r = bench(&format!("ops/adam_apply/{}", tier.as_str()), wu, it, || {
            adam_apply(
                &pool, &mut params, &mut mm, &mut vv, &g, 1e-3, 0.9, 0.999, 1e-8, 0.1, 0.001,
            );
        });
        session.push_items(&r, len);
    }

    println!("\n== wire codecs on a 64k-float gradient window (ambient tier) ==");
    // The q8/topk hot paths dispatch on the PROCESS tier (DYNAMIX_KERNEL),
    // not a pinned pool, so these record whatever tier the run resolved —
    // the session header carries it for cross-run comparison.
    {
        let len = 1 << 16;
        let x: Vec<f32> = (0..len).map(|_| rng.normal() as f32).collect();
        let (wu, it) = iters(10, 100);
        let (mut order, mut idx, mut val) = (Vec::new(), Vec::new(), Vec::new());
        let r = bench("wire/topk_select", wu, it, || {
            wire::topk_encode_into(&x, &mut order, &mut idx, &mut val);
        });
        session.push_items(&r, len);
        let (mut q, mut dense) = (Vec::new(), Vec::new());
        let r = bench("wire/q8_codec", wu, it, || {
            let scale = wire::q8_encode_into(&x, &mut q);
            wire::q8_decode_into(scale, &q, &mut dense).unwrap();
        });
        session.push_items(&r, len);
    }

    println!("\n== persistent pool vs scoped-spawn at a small-bucket matmul ==");
    // The pool's reason to exist: at small problems the per-call
    // thread::scope spawns used to dominate. Same chunk plan, same blocked
    // kernels; only the execution strategy differs.
    {
        let (m, k, n) = (256usize, 128, 64);
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let mut out = vec![0.0f32; m * n];
        let pool = Pool::with_config(threads, KernelTier::Blocked);
        let per = pool.rows_per_chunk(m, 2 * k * n);
        let (wu, it) = iters(20, 200);
        let r_pool = bench("exec/pool_matmul_256x128x64", wu, it, || {
            out.fill(0.0);
            matmul_acc(&pool, &x, &w, m, k, n, &mut out);
        });
        let seq = Pool::with_config(1, KernelTier::Blocked);
        let wref: &[f32] = &w;
        let r_spawn = bench("exec/scoped_spawn_matmul_256x128x64", wu, it, || {
            out.fill(0.0);
            run_scoped(
                x.chunks(per * k)
                    .zip(out.chunks_mut(per * n))
                    .map(|(xc, oc)| {
                        let seq = seq.clone();
                        move || matmul_acc(&seq, xc, wref, xc.len() / k, k, n, oc)
                    })
                    .collect(),
            );
        });
        session.push(&r_pool);
        session.push(&r_spawn);
        let delta = 100.0 * (r_spawn.p50_s - r_pool.p50_s) / r_spawn.p50_s;
        session.set_note(&format!(
            "pool-vs-spawn @256x128x64 t{threads}: pool p50 {:.1}us vs scoped {:.1}us ({delta:+.0}% vs spawn)",
            r_pool.p50_s * 1e6,
            r_spawn.p50_s * 1e6,
        ));
    }

    let path = session.flush()?;
    println!("\nrecorded run -> {}", path.display());
    Ok(())
}
