//! `zero/bytes-per-step` — **modeled payload accounting, not wall-clock**:
//! steady-state wire bytes on the gradient exchange's critical path, and
//! per-shard resident parameter+optimizer floats, for the full-replica
//! dense ring vs the ZeRO reduce-scatter plane under each slice codec.
//!
//!     cargo bench --bench zero
//!
//! Accounting (the same one `ModelRuntime::wire_bytes` feeds the netsim):
//! every figure is an analytic function of the full-size parameter count
//! `P` (paper models, DESIGN.md substitution table) through the *real*
//! [`WireMode::payload_bytes`] codec arithmetic — framing excluded, so
//! the numbers are exact and identical on every re-run (the regression
//! gate sees any change as a codec/accounting change, not noise).
//!
//! * **replica-dense**: the chained ring serializes the full accumulator
//!   through N−1 hops, then broadcasts full params — critical-path bytes
//!   `2·(N−1)·4P`.
//! * **zero-dense**: reduce-scatter + all-gather pipeline one slice of
//!   `ceil(P/N)` params per hop-step — `2·(N−1)·4·ceil(P/N)`, an
//!   `(N−1)/N` reduction (exact up to the ceil).
//! * **zero-topk / zero-q8**: the same schedule with the compressed
//!   per-slice payload (topk: 8 bytes per kept element at 1/4 density;
//!   q8: 1 byte per element + a 4-byte scale) — strictly fewer bytes
//!   than zero-dense at every N.
//!
//! Resident floats per shard: replica keeps `3P` (params + Adam m + v);
//! zero keeps the full `P` param replica for compute but only the owned
//! `ceil(P/N)`-sized m/v slices — `P + 2·ceil(P/N)`.
//!
//! The recorded `*_s` fields carry BYTES (wire rows) or FLOAT COUNTS
//! (resident rows), not seconds — `bench_compare` only needs a stable
//! scalar per name.

use dynamix::comm::wire::WireMode;
use dynamix::trainer::full_size_param_count;
use dynamix::util::bench::{BenchResult, BenchSession};

/// One recorded scalar (bytes or float count) under a stable name.
fn push_value(s: &mut BenchSession, name: &str, v: f64) {
    s.push(&BenchResult {
        name: name.to_string(),
        mean_s: v,
        std_s: 0.0,
        min_s: v,
        p10_s: v,
        p50_s: v,
        p90_s: v,
        n: 1,
    });
}

fn main() -> anyhow::Result<()> {
    let model = "vgg16_mini";
    let p = full_size_param_count(model);
    let mut session = BenchSession::new("zero/bytes-per-step");
    session.set_note(
        "modeled payload accounting (values are bytes / resident f32 counts, NOT \
         seconds), VGG16 full-size gradient: critical-path wire bytes per step \
         and per-shard resident floats, replica ring vs zero reduce-scatter per \
         slice codec; deterministic (exact arithmetic, zero-noise)",
    );
    println!("== {model}: P = {p} full-size params ==");
    for n in [2usize, 4, 8, 16] {
        let hops = 2 * (n - 1);
        let slice = p.div_ceil(n);
        let replica = hops * WireMode::Dense.payload_bytes(p);
        let zero_dense = hops * WireMode::Dense.payload_bytes(slice);
        let zero_topk = hops * WireMode::TopK.payload_bytes(slice);
        let zero_q8 = hops * WireMode::Q8.payload_bytes(slice);
        let reduction = (replica - zero_dense) as f64 / replica as f64;
        println!(
            "  n={n:>2}: replica {replica:>13} B  zero/dense {zero_dense:>12} B \
             ({:.4}% cut)  topk {zero_topk:>11} B  q8 {zero_q8:>11} B",
            100.0 * reduction
        );
        // The tentpole's headline claim, in executable form: the zero
        // plane cuts wire bytes by (N−1)/N (exactly, up to the ceil on
        // the slice size), and every compressed codec cuts further.
        assert!(
            reduction >= (n - 1) as f64 / n as f64 - 1e-6,
            "n={n}: reduce-scatter reduction {reduction} below (N-1)/N"
        );
        assert!(
            zero_topk < zero_dense && zero_q8 < zero_dense,
            "n={n}: compressed codec not strictly cheaper ({zero_topk}/{zero_q8} vs {zero_dense})"
        );
        push_value(&mut session, &format!("n{n:02}/wire/replica-dense"), replica as f64);
        push_value(&mut session, &format!("n{n:02}/wire/zero-dense"), zero_dense as f64);
        push_value(&mut session, &format!("n{n:02}/wire/zero-topk"), zero_topk as f64);
        push_value(&mut session, &format!("n{n:02}/wire/zero-q8"), zero_q8 as f64);

        let resident_replica = 3 * p;
        let resident_zero = p + 2 * slice;
        assert!(resident_zero < resident_replica, "n={n}: zero plane grew resident state");
        push_value(
            &mut session,
            &format!("n{n:02}/resident/replica-floats"),
            resident_replica as f64,
        );
        push_value(
            &mut session,
            &format!("n{n:02}/resident/zero-floats"),
            resident_zero as f64,
        );
    }
    let path = session.flush()?;
    println!("recorded run -> {}", path.display());
    Ok(())
}
