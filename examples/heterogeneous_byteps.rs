//! §VI-G scenario: heterogeneous GPUs + parameter-server synchronization.
//!
//! Recreates the FABRIC testbed shape — 4 fast (RTX3090-like) and 4 slow
//! (T4-like) workers under a BytePS-style parameter-server topology — and
//! shows DYNAMIX assigning *non-uniform* per-worker batch sizes, which a
//! static policy cannot do. Watch the per-worker batch vector: fast
//! workers end up with larger batches than the T4s.
//!
//!     cargo run --release --example heterogeneous_byteps

use dynamix::config::presets;
use dynamix::coordinator::Coordinator;
use dynamix::metrics::RunRecord;
use dynamix::runtime::default_backend;

fn main() -> anyhow::Result<()> {
    let store = default_backend()?;
    let cfg = presets::by_name("byteps-hetero")?;
    println!(
        "cluster: {} workers (hetero: 4x RTX3090-like + 4x T4-like), topology={}",
        cfg.cluster.n_workers,
        cfg.cluster.topology.as_str()
    );

    let mut coord = Coordinator::new(cfg, store)?;
    println!("\n--- training arbitrator (3 episodes) ---");
    for r in coord.train_rl(3)? {
        println!(
            "episode {}: mean_R={:+.2} eval_acc={:.3}",
            r.episode, r.mean_return, r.final_eval_acc
        );
    }

    println!("\n--- inference: watch per-worker batch allocation ---");
    let mut record = RunRecord::new("byteps-example");
    let summary = coord.run_inference(20, &mut record)?;
    println!(
        "final batches per worker (0-3 fast, 4-7 slow): {:?}",
        coord.trainer.batches
    );
    let fast: usize = coord.trainer.batches[..4].iter().sum();
    let slow: usize = coord.trainer.batches[4..].iter().sum();
    println!(
        "fast-half total batch = {fast}, slow-half = {slow} \
         (straggler mitigation => expect fast >= slow)"
    );
    println!(
        "final eval acc {:.3} at sim t={:.0}s",
        summary.final_eval_acc, summary.total_sim_time
    );
    Ok(())
}
