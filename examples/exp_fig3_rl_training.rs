//! Regenerates paper Fig. 3 (RL training reward curves + policy snapshots).
//! Usage: cargo run --release --example exp_fig3_rl_training -- [quick|full] [preset]
use dynamix::{config::Scale, harness};
use dynamix::runtime::default_backend;

fn main() -> anyhow::Result<()> {
    let scale = Scale::parse(&std::env::args().nth(1).unwrap_or("quick".into()))?;
    let store = default_backend()?;
    match std::env::args().nth(2) {
        Some(preset) => {
            harness::fig3_rl_training(store, &preset, scale, None)?;
        }
        None => {
            for preset in ["vgg11-sgd", "vgg11-adam", "resnet34-sgd"] {
                harness::fig3_rl_training(store.clone(), preset, scale, None)?;
            }
        }
    }
    Ok(())
}
