//! Regenerates paper §VI-H (decision-making overhead analysis).
//! Usage: cargo run --release --example exp_overhead -- [cycles]
use dynamix::harness;
use dynamix::runtime::default_backend;

fn main() -> anyhow::Result<()> {
    let cycles: usize = std::env::args().nth(1).map(|s| s.parse()).transpose()?.unwrap_or(10);
    let store = default_backend()?;
    harness::overhead_analysis(store, cycles)?;
    Ok(())
}
