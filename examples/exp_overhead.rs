//! Regenerates paper §VI-H (decision-making overhead analysis).
//! Usage: cargo run --release --example exp_overhead -- [cycles]
use dynamix::{harness, runtime::ArtifactStore};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let cycles: usize = std::env::args().nth(1).map(|s| s.parse()).transpose()?.unwrap_or(10);
    let store = Arc::new(ArtifactStore::open_default()?);
    harness::overhead_analysis(store, cycles)?;
    Ok(())
}
