//! Regenerates paper Fig. 2 (static-batch baseline trajectories).
//! Usage: cargo run --release --example exp_fig2_baselines -- [quick|full]
use dynamix::{config::Scale, harness};
use dynamix::runtime::default_backend;

fn main() -> anyhow::Result<()> {
    let scale = Scale::parse(&std::env::args().nth(1).unwrap_or("quick".into()))?;
    let store = default_backend()?;
    harness::fig2_baselines(store, scale)?;
    Ok(())
}
