//! Distributed deployment demo: real TCP leader/worker protocol.
//!
//! Spawns the DYNAMIX leader (PPO arbitrator) plus 3 worker threads in one
//! process, connected over localhost TCP with the production wire protocol
//! (`comm::Msg`). The data plane is REAL synchronous data-parallel
//! training: each worker draws its shard's rows, the gradient accumulator
//! rings through the workers (chained deterministic reduction), and every
//! worker applies the identical reduced update to its parameter replica —
//! replicas stay bit-identical without ever shipping parameters. The
//! leader scores reported window states and pushes batch-size actions.
//! Same code path as `dynamix serve` / `dynamix worker` across machines.
//!
//!     cargo run --release --example distributed

use dynamix::comm::leader;
use dynamix::config::Scale;
use std::thread;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let preset = "vgg11-sgd";
    let bind = "127.0.0.1:17077";
    const WORKERS: usize = 3;
    const CYCLES: usize = 6;

    let leader_handle =
        thread::spawn(move || leader::serve_n(bind, preset, Scale::Quick, WORKERS, CYCLES));
    thread::sleep(Duration::from_millis(300));

    let mut workers = Vec::new();
    for id in 0..WORKERS as u32 {
        workers.push(thread::spawn(move || {
            leader::worker(bind, preset, Scale::Quick, id)
        }));
    }
    for (i, w) in workers.into_iter().enumerate() {
        w.join().unwrap().map_err(|e| anyhow::anyhow!("worker {i}: {e}"))?;
    }
    leader_handle
        .join()
        .unwrap()
        .map_err(|e| anyhow::anyhow!("leader: {e}"))?;
    println!("distributed demo complete: {WORKERS} workers coordinated over TCP");
    Ok(())
}
