//! Regenerates paper Table I (scalability at 8/16/32 nodes).
//! Usage: cargo run --release --example exp_table1_scalability -- [quick|full]
use dynamix::{config::Scale, harness};
use dynamix::runtime::default_backend;

fn main() -> anyhow::Result<()> {
    let scale = Scale::parse(&std::env::args().nth(1).unwrap_or("quick".into()))?;
    let store = default_backend()?;
    harness::table1_scalability(store, scale)?;
    Ok(())
}
