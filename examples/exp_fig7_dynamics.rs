//! Regenerates the dynamic-environment scenario comparison (fig7): the
//! frozen DYNAMIX policy vs static baselines and the GNS heuristic under
//! identical scripted timelines (preemption/rejoin, bandwidth collapse,
//! congestion storms, load shifts).
//! Usage: cargo run --release --example exp_fig7_dynamics -- [quick|full]
use dynamix::{config::Scale, harness};
use dynamix::runtime::default_backend;

fn main() -> anyhow::Result<()> {
    let scale = Scale::parse(&std::env::args().nth(1).unwrap_or("quick".into()))?;
    let store = default_backend()?;
    harness::fig7_dynamics(store, scale)?;
    Ok(())
}
