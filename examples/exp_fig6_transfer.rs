//! Regenerates paper Fig. 6 (policy transfer across model families).
//! Usage: cargo run --release --example exp_fig6_transfer -- [quick|full]
use dynamix::{config::Scale, harness};
use dynamix::runtime::default_backend;

fn main() -> anyhow::Result<()> {
    let scale = Scale::parse(&std::env::args().nth(1).unwrap_or("quick".into()))?;
    let store = default_backend()?;
    harness::fig6_transfer(store.clone(), "transfer-vgg16-src", "transfer-vgg19-dst", scale)?;
    harness::fig6_transfer(store, "transfer-resnet34-src", "transfer-resnet50-dst", scale)?;
    Ok(())
}
