//! Quickstart: the smallest end-to-end DYNAMIX loop.
//!
//! Builds a 4-worker simulated cluster training `vgg11_mini` (SGD) on the
//! synthetic CIFAR-10 stand-in, runs a few PPO decision cycles, and prints
//! what the arbitrator decides. Runs on the native backend out of the box
//! (`make artifacts` + the backend-xla feature switch to the PJRT path).
//!
//!     cargo run --release --example quickstart

use dynamix::config::ExperimentConfig;
use dynamix::coordinator::Coordinator;
use dynamix::metrics::RunRecord;
use dynamix::runtime::default_backend;

fn main() -> anyhow::Result<()> {
    let store = default_backend()?;
    println!(
        "backend: {}, models: {:?}",
        store.name(),
        store.schema().models.keys().collect::<Vec<_>>()
    );

    let mut cfg = ExperimentConfig::default();
    cfg.name = "quickstart".into();
    cfg.cluster.n_workers = 4;
    cfg.batch.initial = 64;
    cfg.rl.k = 3;
    cfg.steps_per_episode = 8;

    // 1. Train the PPO arbitrator for two short episodes.
    let mut coord = Coordinator::new(cfg, store)?;
    println!("\n--- RL training (2 episodes) ---");
    for r in coord.train_rl(2)? {
        println!(
            "episode {}: mean_return={:+.2} final_eval_acc={:.3} sim_time={:.0}s",
            r.episode, r.mean_return, r.final_eval_acc, r.sim_time
        );
    }

    // 2. Deploy the learned policy greedily.
    println!("\n--- inference (frozen policy) ---");
    let mut record = RunRecord::new("quickstart");
    let summary = coord.run_inference(8, &mut record)?;
    for p in &record.points {
        println!(
            "cycle@iter {:3}  sim_t={:6.1}s  train_acc={:.3}  eval_acc={:.3}  batch={:.0}±{:.0}",
            p.iter, p.sim_time, p.train_acc, p.eval_acc, p.batch_mean, p.batch_std
        );
    }
    println!(
        "\nfinal eval acc {:.3} after {} iterations ({:.0} simulated seconds)",
        summary.final_eval_acc, summary.total_iters, summary.total_sim_time
    );
    Ok(())
}
