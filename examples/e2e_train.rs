//! End-to-end validation driver (DESIGN.md / EXPERIMENTS.md §E2E).
//!
//! Proves all three layers compose on a real workload: a 16-worker
//! simulated BSP cluster trains `vgg11_mini` (every dense layer runs the
//! L1 Pallas kernel inside the L2 AOT train-step HLO, executed by the L3
//! Rust runtime via PJRT) for a few hundred global iterations under
//! DYNAMIX control, logging the loss curve and the batch-size schedule.
//!
//!     cargo run --release --example e2e_train -- [episodes] [cycles]
//!
//! Writes runs/e2e/loss_curve.csv + runs/e2e/summary.json.

use dynamix::config::presets;
use dynamix::coordinator::Coordinator;
use dynamix::metrics::RunRecord;
use dynamix::runtime::default_backend;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let episodes: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(4);
    let cycles: usize = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(60);

    let store = default_backend()?;
    let mut cfg = presets::by_name("vgg11-sgd")?;
    cfg.steps_per_episode = 40;
    cfg.train.max_steps = cfg.steps_per_episode * cfg.rl.k;

    println!(
        "e2e: {} workers, model={}, {} episodes of {} cycles, then inference",
        cfg.cluster.n_workers, cfg.train.model, episodes, cfg.steps_per_episode
    );
    let t0 = std::time::Instant::now();
    let mut coord = Coordinator::new(cfg, store)?;

    println!("\n=== phase 1: PPO training ===");
    for r in coord.train_rl(episodes)? {
        println!(
            "episode {:2}: mean_R={:+7.2}  median_R={:+7.2}  eval_acc={:.3}  sim_t={:6.0}s",
            r.episode, r.mean_return, r.median_return, r.final_eval_acc, r.sim_time
        );
    }

    println!("\n=== phase 2: inference to convergence ===");
    let mut record = RunRecord::new("e2e-vgg11-sgd");
    let summary = coord.run_inference(cycles, &mut record)?;
    println!("  iter   sim_t    loss   train  eval   batch");
    for p in &record.points {
        println!(
            "  {:4}  {:6.1}s  {:.3}  {:.3}  {:.3}  {:4.0}±{:.0}",
            p.iter, p.sim_time, p.loss, p.train_acc, p.eval_acc, p.batch_mean, p.batch_std
        );
    }

    let runs = dynamix::harness::runs_dir().join("e2e");
    std::fs::create_dir_all(&runs)?;
    record.save_csv(&runs.join("loss_curve.csv"))?;
    record.save_json(&runs.join("summary.json"))?;

    let exec = &coord.trainer.runtime;
    println!(
        "\ne2e done in {:.0}s wall: {} PJRT steps ({:.1}ms mean), final eval acc {:.3}, \
         convergence at sim t={:?}",
        t0.elapsed().as_secs_f64(),
        exec.exec_count,
        exec.exec_seconds_total / exec.exec_count.max(1) as f64 * 1e3,
        summary.final_eval_acc,
        summary.convergence_time,
    );
    println!("wrote {}", runs.join("loss_curve.csv").display());
    anyhow::ensure!(
        summary.final_eval_acc > 0.5,
        "e2e failed: eval accuracy {:.3} below sanity floor",
        summary.final_eval_acc
    );
    Ok(())
}
