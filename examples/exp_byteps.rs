//! Regenerates paper §VI-G (BytePS parameter-server + heterogeneous GPUs).
//! Usage: cargo run --release --example exp_byteps -- [quick|full]
use dynamix::{config::Scale, harness};
use dynamix::runtime::default_backend;

fn main() -> anyhow::Result<()> {
    let scale = Scale::parse(&std::env::args().nth(1).unwrap_or("quick".into()))?;
    let store = default_backend()?;
    harness::byteps_integration(store, scale)?;
    Ok(())
}
