//! Regenerates paper Figs. 4+5 (inference trajectories + batch adaptation).
//! Usage: cargo run --release --example exp_fig4_fig5_inference -- [quick|full] [preset]
use dynamix::{config::Scale, harness};
use dynamix::runtime::default_backend;

fn main() -> anyhow::Result<()> {
    let scale = Scale::parse(&std::env::args().nth(1).unwrap_or("quick".into()))?;
    let store = default_backend()?;
    match std::env::args().nth(2) {
        Some(preset) => {
            harness::fig4_fig5_inference(store, &preset, scale, None)?;
        }
        None => {
            for preset in ["vgg11-sgd", "vgg11-adam", "resnet34-sgd"] {
                harness::fig4_fig5_inference(store.clone(), preset, scale, None)?;
            }
        }
    }
    Ok(())
}
